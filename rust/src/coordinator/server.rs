//! The serving loop: worker thread draining the batcher, executing batches
//! through a pluggable executor (the native engine in production, a stub in
//! tests), and co-running the performance simulator for per-batch
//! accelerator estimates.
//!
//! Execution is **per-request honest**: the executor returns one `Result`
//! per request, the worker fulfills each request's
//! [`Completion`](super::Completion) slot with it, and only the requests
//! that actually completed enter the
//! completion/latency statistics — a submitter always learns *which*
//! request in a batch died, not just that something did. Between executor
//! calls the worker runs **continuous admission**: decode-phase requests of
//! the executing (model, policy) key that arrived meanwhile join immediately
//! (bounded by the fairness streak), so token streams never wait out the
//! batching budget behind prefill traffic.
//!
//! The loop is **fault-tolerant** (see [`Resilience`]): executor panics are
//! caught per batch (the worker survives and keeps draining), failed
//! requests re-enqueue with exponential backoff up to `max_retries` — a
//! decode retry first rolls its session's KV back to the ledger's committed
//! token count so the re-executed step is bit-identical to a first attempt
//! — per-request deadlines settle expired work at batch cut without
//! executing it, and a bounded queue sheds new prefills (never in-flight
//! decode streams) once it backs up, surfacing as `degraded` in
//! [`Metrics`]. A completion slot is write-once, so only the attempt that
//! finally settles a request resolves it.
//!
//! When the executor allocates KV from a budgeted
//! [`KvPagePool`](crate::kernels::KvPagePool) ([`ServerConfig::kv_pool`]),
//! the worker additionally watches the pool: a **hard** allocation failure
//! (budget exhausted and nothing left to preempt) latches the server into
//! MemoryPressure — new prefills shed with the distinct [`ERR_SHED_MEM`]
//! reason while decode streams keep running — and the latch clears with
//! hysteresis once pool usage drops below half the budget. Pool gauges
//! (`kv_pages_in_use`, preemption counts) are sampled into every
//! [`Metrics`] snapshot, alongside the co-simulated per-session KV
//! footprint (`kv_bytes_simulated`, priced by
//! [`sim::kv_session_footprint`] from the worker's token ledger).
//!
//! When [`ServerConfig::recorder`] is enabled the worker additionally
//! traces the serving lifecycle: `request` / `request.queue` /
//! `request.exec` spans per successful request (queue wait split from
//! execution) and one `batch.execute` span per executor call whose duration
//! is exactly the host seconds credited to [`Metrics::host_exec_s`], so the
//! trace's execute spans sum to the metric. The whole serving loop runs
//! inside an [`obs::with_current`] scope, which is how the kernel-level
//! counters and spans (see [`crate::obs`]) reach the same sink without any
//! executor plumbing.

use super::batcher::{Batch, BatchPolicy, Batcher, Phase, Request};
use super::completion::RequestResult;
use crate::baselines::FlexiBitAccel;
use crate::obs::{
    self, DriftAudit, DriftBound, Histogram, Recorder, SpanEvent, PID_EXEC, PID_REQUEST,
};
use crate::sim::{self, AcceleratorConfig};
use crate::workload::{ModelSpec, PrecisionPolicy};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Aggregated serving metrics. Completion/latency stats count only requests
/// whose executor result was `Ok`; failed requests land in
/// [`Metrics::requests_failed_exec`] / [`Metrics::requests_failed_shutdown`]
/// / `batches_failed` so SLO accounting stays truthful, and they are
/// excluded from the latency/batch-size histograms and the span stream the
/// same way.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub requests_completed: u64,
    /// Requests whose executor result was an error (individually, or via a
    /// whole-batch failure). Excluded from completion, latency, and
    /// co-simulation stats.
    pub requests_failed_exec: u64,
    /// Requests settled with an error because the server shut down before
    /// executing them.
    pub requests_failed_shutdown: u64,
    pub batches_executed: u64,
    pub batches_failed: u64,
    pub total_batch_size: u64,
    /// Wall-clock execution seconds (host).
    pub host_exec_s: f64,
    /// Request latency (arrival → completion), successful requests only.
    /// Carries the exact sum/max plus log-bucketed quantiles (p50/p95/p99).
    pub latency: Histogram,
    /// Per-step latency of decode-phase requests (a subset of `latency`).
    pub decode_latency: Histogram,
    /// Latency of prefill-phase requests, session or stateless (the other
    /// subset of `latency`) — per-phase SLOs need both tails separately.
    pub prefill_latency: Histogram,
    /// Completed requests per executed batch: `count()` tracks
    /// `batches_executed`, `sum()` tracks `total_batch_size`.
    pub batch_size: Histogram,
    /// Simulated accelerator seconds (FlexiBit model).
    pub sim_accel_s: f64,
    /// Simulated accelerator energy (J).
    pub sim_energy_j: f64,
    pub reconfigurations: u64,
    /// Token-stream sessions opened (completed session prefills).
    pub sessions_started: u64,
    /// Autoregressive decode steps completed.
    pub decode_steps: u64,
    /// Failed attempts re-enqueued under the retry policy (per attempt, so
    /// one request retried twice counts 2).
    pub retries: u64,
    /// Requests that completed on a retry attempt (attempt > 0) — the
    /// recovered half of `retries`.
    pub retry_success: u64,
    /// Prefill requests rejected at submit by the admission-control queue
    /// bound (their completions resolve [`ERR_SHED`] without executing).
    pub requests_shed: u64,
    /// Requests whose deadline expired before execution (resolved
    /// [`ERR_DEADLINE`] at dequeue/batch cut, never executed).
    pub requests_failed_deadline: u64,
    /// Executor panics caught by the worker's isolation boundary; each also
    /// counts in `batches_failed` once its requests exhaust their retries.
    pub batches_panicked: u64,
    /// Backoff delay scheduled per retry, seconds (count tracks `retries`).
    pub retry_backoff: Histogram,
    /// Admission-control state: set when a request is shed, cleared once
    /// the queue drains below half its bound (hysteresis, so the flag does
    /// not flap at the boundary). See [`Metrics::health`].
    pub degraded: bool,
    /// Prefill requests shed at submit while the server was under memory
    /// pressure (resolved [`ERR_SHED_MEM`], never executed) — a separate
    /// ledger from the queue-bound `requests_shed` so capacity shedding
    /// and memory shedding stay distinguishable in every exporter.
    pub requests_shed_mem: u64,
    /// Memory-pressure state: latched by the worker when the KV page pool
    /// reports a hard allocation failure (budget exhausted and nothing left
    /// to preempt), cleared with hysteresis once pool usage drops below
    /// half the budget. See [`Metrics::health`].
    pub mem_pressure: bool,
    /// Sessions the executor preempted (KV pages dropped, token history
    /// kept) to free pool budget; each preempted stream re-prefills
    /// bit-identically on its next step. Sampled from the pool.
    pub sessions_preempted: u64,
    /// Live KV pages in the pool (gauge, sampled each worker iteration).
    pub kv_pages_in_use: u64,
    /// Bytes of packed KV page words resident in the pool (gauge, sampled).
    pub kv_bytes_in_use: u64,
    /// Co-simulated KV footprint (gauge, bytes): every ledger session priced
    /// by [`sim::kv_session_footprint`] under its own policy. For unshared
    /// sessions this tracks `kv_bytes_in_use` exactly; under CoW prefix
    /// sharing it is the upper bound (shared pages priced once per session).
    pub kv_bytes_simulated: u64,
    /// Sim-vs-measured drift auditor: per-(pair, kind, shape-class) ratio
    /// histograms joining every executed batch's wall time with its
    /// co-simulated predicted cost, plus utilization attribution. Every
    /// executed batch lands here exactly once (audited or skipped).
    pub drift: DriftAudit,
}

/// The one zero-denominator guard behind every metrics ratio: a mean or
/// rate over an empty (or degenerate) window is 0, never NaN/inf.
fn ratio(num: f64, den: f64) -> f64 {
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

impl Metrics {
    /// Requests that failed for any reason: executor error,
    /// shutdown-settled, deadline-expired, or shed at admission.
    pub fn requests_failed(&self) -> u64 {
        self.requests_failed_exec
            + self.requests_failed_shutdown
            + self.requests_failed_deadline
            + self.requests_shed
            + self.requests_shed_mem
    }

    /// Healthy/Degraded/MemoryPressure serving state (the admission-control
    /// view; see [`Metrics::degraded`] and [`Metrics::mem_pressure`]).
    /// Memory pressure dominates: a queue backlog is a throughput problem,
    /// an exhausted KV pool is a capacity problem.
    pub fn health(&self) -> &'static str {
        if self.mem_pressure {
            "memory_pressure"
        } else if self.degraded {
            "degraded"
        } else {
            "healthy"
        }
    }

    /// Requests that left the system, successfully or not — the drain
    /// condition for streams that may contain failing batches.
    pub fn requests_finished(&self) -> u64 {
        self.requests_completed + self.requests_failed()
    }

    pub fn mean_latency_s(&self) -> f64 {
        ratio(self.latency.sum(), self.latency.count() as f64)
    }

    /// Exact maximum observed request latency.
    pub fn latency_max_s(&self) -> f64 {
        self.latency.max()
    }

    /// Request-latency quantile (e.g. `0.5`, `0.95`, `0.99`) from the
    /// log-bucketed histogram.
    pub fn latency_p(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    pub fn mean_batch_size(&self) -> f64 {
        ratio(self.total_batch_size as f64, self.batches_executed as f64)
    }

    pub fn throughput_rps(&self, wall_s: f64) -> f64 {
        ratio(self.requests_completed as f64, wall_s)
    }

    /// Human-readable multi-line summary (the first of the three exporters;
    /// see also [`Metrics::prometheus_text`] and [`obs::chrome_trace`]).
    pub fn summary(&self, wall_s: f64) -> String {
        let ms = 1e3;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "requests: {} completed, {} failed ({} exec / {} shutdown)",
            self.requests_completed,
            self.requests_failed(),
            self.requests_failed_exec,
            self.requests_failed_shutdown,
        );
        let _ = writeln!(
            out,
            "batches:  {} executed (mean size {:.2}), {} failed, {} reconfigurations",
            self.batches_executed,
            self.mean_batch_size(),
            self.batches_failed,
            self.reconfigurations,
        );
        let _ = writeln!(
            out,
            "latency:  mean {:.3} ms, p50 {:.3}, p95 {:.3}, p99 {:.3}, max {:.3} ms",
            self.mean_latency_s() * ms,
            self.latency_p(0.50) * ms,
            self.latency_p(0.95) * ms,
            self.latency_p(0.99) * ms,
            self.latency_max_s() * ms,
        );
        if self.prefill_latency.count() > 0 {
            let _ = writeln!(
                out,
                "prefill:  {} requests, p50 {:.3} ms, p95 {:.3}, p99 {:.3} ms",
                self.prefill_latency.count(),
                self.prefill_latency.quantile(0.50) * ms,
                self.prefill_latency.quantile(0.95) * ms,
                self.prefill_latency.quantile(0.99) * ms,
            );
        }
        if self.decode_steps > 0 {
            let _ = writeln!(
                out,
                "decode:   {} steps ({} sessions), p50 {:.3} ms, p99 {:.3} ms",
                self.decode_steps,
                self.sessions_started,
                self.decode_latency.quantile(0.50) * ms,
                self.decode_latency.quantile(0.99) * ms,
            );
        }
        let faults = self.retries
            + self.requests_shed
            + self.requests_shed_mem
            + self.requests_failed_deadline
            + self.batches_panicked;
        if faults > 0 || self.degraded || self.mem_pressure {
            let _ = writeln!(
                out,
                "faults:   {} retries ({} recovered), {} shed (+{} mem), \
                 {} deadline misses, {} panics caught, state {}",
                self.retries,
                self.retry_success,
                self.requests_shed,
                self.requests_shed_mem,
                self.requests_failed_deadline,
                self.batches_panicked,
                self.health(),
            );
        }
        if self.sessions_preempted > 0 || self.kv_pages_in_use > 0 {
            let _ = writeln!(
                out,
                "kv:       {} pages resident ({} KiB), {} sessions preempted",
                self.kv_pages_in_use,
                self.kv_bytes_in_use / 1024,
                self.sessions_preempted,
            );
        }
        out.push_str(&self.drift.summary_lines());
        let _ = writeln!(
            out,
            "host:     exec {:.3} s, sim {:.4} s / {:.4} J, {:.1} req/s over {:.3} s wall",
            self.host_exec_s,
            self.sim_accel_s,
            self.sim_energy_j,
            self.throughput_rps(wall_s),
            wall_s,
        );
        out
    }

    /// Prometheus text-format dump: serving counters and gauges, full
    /// cumulative-bucket histograms (plus p50/p95/p99 gauges) for the
    /// latency/batch-size distributions, the drift auditor's series, and
    /// the recorder's kernel counters (all-zero from a disabled recorder,
    /// so the scrape shape is stable).
    pub fn prometheus_text(&self, recorder: &Recorder, wall_s: f64) -> String {
        let mut out = String::new();
        let counters: [(&str, u64); 16] = [
            ("requests_completed", self.requests_completed),
            ("requests_failed_exec", self.requests_failed_exec),
            ("requests_failed_shutdown", self.requests_failed_shutdown),
            ("requests_failed_deadline", self.requests_failed_deadline),
            ("requests_shed", self.requests_shed),
            ("requests_shed_mem", self.requests_shed_mem),
            ("batches_executed", self.batches_executed),
            ("batches_failed", self.batches_failed),
            ("batches_panicked", self.batches_panicked),
            ("total_batch_size", self.total_batch_size),
            ("reconfigurations", self.reconfigurations),
            ("sessions_started", self.sessions_started),
            ("decode_steps", self.decode_steps),
            ("retries", self.retries),
            ("retry_success", self.retry_success),
            ("sessions_preempted", self.sessions_preempted),
        ];
        for (name, v) in counters {
            let _ = writeln!(out, "# TYPE flexibit_{name} counter");
            let _ = writeln!(out, "flexibit_{name} {v}");
        }
        let gauges: [(&str, f64); 9] = [
            ("host_exec_seconds", self.host_exec_s),
            ("sim_accel_seconds", self.sim_accel_s),
            ("sim_energy_joules", self.sim_energy_j),
            ("throughput_rps", self.throughput_rps(wall_s)),
            ("degraded", if self.degraded { 1.0 } else { 0.0 }),
            ("memory_pressure", if self.mem_pressure { 1.0 } else { 0.0 }),
            ("kv_pages_in_use", self.kv_pages_in_use as f64),
            ("kv_bytes_in_use", self.kv_bytes_in_use as f64),
            ("kv_bytes_simulated", self.kv_bytes_simulated as f64),
        ];
        for (name, v) in gauges {
            let _ = writeln!(out, "# TYPE flexibit_{name} gauge");
            let _ = writeln!(out, "flexibit_{name} {v}");
        }
        for (name, h) in self.histograms() {
            // Full cumulative-bucket histograms (scrapeable: a Prometheus
            // server can compute any quantile via histogram_quantile) plus
            // precomputed p50/p95/p99 convenience gauges — a `histogram`
            // metric cannot carry quantile series under its own name.
            out.push_str(&obs::prometheus_histogram(name, h));
            for (suffix, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
                let _ = writeln!(out, "# TYPE flexibit_{name}_{suffix} gauge");
                let _ = writeln!(out, "flexibit_{name}_{suffix} {}", h.quantile(q));
            }
        }
        out.push_str(&self.drift.prometheus_text());
        out.push_str(&obs::prometheus_counters(recorder));
        out
    }

    /// The serving histograms by stable export name.
    fn histograms(&self) -> [(&'static str, &Histogram); 5] {
        [
            ("request_latency_seconds", &self.latency),
            ("prefill_latency_seconds", &self.prefill_latency),
            ("decode_latency_seconds", &self.decode_latency),
            ("batch_size", &self.batch_size),
            ("retry_backoff_seconds", &self.retry_backoff),
        ]
    }

    /// The standalone drift-report exporter: the auditor's JSON (schema
    /// `flexibit.drift.v1`) — per-key measured/predicted ratio stats,
    /// violations against the configured bound, utilization attribution.
    pub fn drift_report(&self) -> String {
        self.drift.report_json()
    }

    /// Machine-readable serving report (JSON object, schema
    /// `flexibit.metrics.v4` — v4 split memory-pressure shedding from queue
    /// shedding and added the KV-pool fields (`requests_shed_mem`,
    /// `sessions_preempted`, `kv_pages_in_use`, `kv_bytes_in_use`,
    /// `kv_bytes_simulated`, `memory_pressure`) to `robustness`; v3
    /// switched batch keys and drift
    /// labels to precision-policy labels/digests; v2 added the `robustness`
    /// member and the deadline/shed request counters): the same shape
    /// `loadgen` embeds in its own report, written standalone by
    /// `serve --metrics-out`.
    pub fn report_json(&self, wall_s: f64) -> String {
        format!("{{\"schema\":\"flexibit.metrics.v4\",{}}}", self.report_fields(wall_s))
    }

    /// The inner fields of [`Metrics::report_json`], without the enclosing
    /// braces/schema — shared so `loadgen` can wrap them with its scenario
    /// echo and token accounting while staying byte-compatible on the
    /// common part.
    pub fn report_fields(&self, wall_s: f64) -> String {
        use crate::obs::json_num as n;
        let phase = |h: &Histogram| {
            format!(
                "{{\"count\":{},\"goodput_rps\":{},\"mean_ms\":{},\"p50_ms\":{},\
                 \"p95_ms\":{},\"p99_ms\":{},\"max_ms\":{}}}",
                h.count(),
                n(ratio(h.count() as f64, wall_s)),
                n(h.mean() * 1e3),
                n(h.quantile(0.50) * 1e3),
                n(h.quantile(0.95) * 1e3),
                n(h.quantile(0.99) * 1e3),
                n(h.max() * 1e3),
            )
        };
        let mut out = String::new();
        let _ = write!(out, "\"wall_s\":{},", n(wall_s));
        let _ = write!(
            out,
            "\"requests\":{{\"completed\":{},\"failed_exec\":{},\"failed_shutdown\":{},\
             \"failed_deadline\":{},\"shed\":{},\"sessions_started\":{},\"decode_steps\":{}}},",
            self.requests_completed,
            self.requests_failed_exec,
            self.requests_failed_shutdown,
            self.requests_failed_deadline,
            self.requests_shed,
            self.sessions_started,
            self.decode_steps,
        );
        let _ = write!(
            out,
            "\"phases\":{{\"all\":{},\"prefill\":{},\"decode\":{}}},",
            phase(&self.latency),
            phase(&self.prefill_latency),
            phase(&self.decode_latency),
        );
        let _ = write!(
            out,
            "\"batches\":{{\"executed\":{},\"failed\":{},\"mean_size\":{},\
             \"reconfigurations\":{}}},",
            self.batches_executed,
            self.batches_failed,
            n(self.mean_batch_size()),
            self.reconfigurations,
        );
        let _ = write!(
            out,
            "\"host\":{{\"exec_s\":{},\"sim_accel_s\":{},\"sim_energy_j\":{},\
             \"throughput_rps\":{}}},",
            n(self.host_exec_s),
            n(self.sim_accel_s),
            n(self.sim_energy_j),
            n(self.throughput_rps(wall_s)),
        );
        let _ = write!(
            out,
            "\"robustness\":{{\"retries\":{},\"retry_success\":{},\"requests_shed\":{},\
             \"requests_shed_mem\":{},\"deadline_misses\":{},\"batches_panicked\":{},\
             \"degraded\":{},\"memory_pressure\":{},\"sessions_preempted\":{},\
             \"kv_pages_in_use\":{},\"kv_bytes_in_use\":{},\"kv_bytes_simulated\":{}}},",
            self.retries,
            self.retry_success,
            self.requests_shed,
            self.requests_shed_mem,
            self.requests_failed_deadline,
            self.batches_panicked,
            self.degraded,
            self.mem_pressure,
            self.sessions_preempted,
            self.kv_pages_in_use,
            self.kv_bytes_in_use,
            self.kv_bytes_simulated,
        );
        let _ = write!(out, "\"drift\":{}", self.drift.report_json());
        out
    }
}

/// Error text a deadline-expired request resolves with (never executed).
pub const ERR_DEADLINE: &str = "deadline exceeded before execution";
/// Error text a request shed by admission control resolves with.
pub const ERR_SHED: &str = "queue full: request shed by admission control";
/// Error text a prefill shed under memory pressure resolves with — distinct
/// from [`ERR_SHED`] so clients (and the shed counters) can tell a deep
/// queue from an exhausted KV page pool.
pub const ERR_SHED_MEM: &str = "memory pressure: request shed by admission control";

/// Fault-tolerance policy: bounded retries, per-request deadlines, and
/// admission control. The default is the pre-fault-tolerance behavior —
/// fail fast, no deadline, unbounded queue — so existing callers are
/// unchanged.
#[derive(Debug, Clone)]
pub struct Resilience {
    /// Re-executions granted after a failed attempt (0 = fail fast). A
    /// request's completion is only resolved by its final attempt.
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per subsequent attempt
    /// (capped at 2^20x to stay finite under absurd retry budgets).
    pub retry_backoff: Duration,
    /// Default deadline budget (arrival → completion) stamped at submit on
    /// requests that carry none. `None` = no deadline.
    pub default_deadline: Option<Duration>,
    /// Admission-control queue bound (0 = unbounded): at or past it, new
    /// prefill requests are shed while decode steps of in-flight sessions
    /// (and `End` control messages) are always admitted — backpressure must
    /// not corrupt a live token stream.
    pub queue_bound: usize,
}

impl Default for Resilience {
    fn default() -> Self {
        Resilience {
            max_retries: 0,
            retry_backoff: Duration::from_millis(1),
            default_deadline: None,
            queue_bound: 0,
        }
    }
}

/// Server configuration.
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Accelerator scale the co-simulation estimates against.
    pub sim_config: AcceleratorConfig,
    /// Model spec used by the co-simulation (per-token GEMM shapes).
    pub sim_model: ModelSpec,
    /// Observability sink for spans and kernel counters.
    /// [`Recorder::disabled`] (the default) reduces every instrumentation
    /// point to a branch.
    pub recorder: Recorder,
    /// Drift gate: when set, every audited batch's measured/predicted
    /// ratio is checked against the bound and violations are counted (and
    /// logged) — the server fails loudly when the analytical model and the
    /// measured hot path diverge. `None` audits without gating.
    pub drift: Option<DriftBound>,
    /// Fault-tolerance policy (retries, deadlines, admission control).
    pub resilience: Resilience,
    /// The KV page pool the executor allocates from, when serving runs
    /// under a byte budget (`--kv-budget-mb`). The worker samples its
    /// gauges into [`Metrics`] and drives the memory-pressure latch from
    /// its hard-failure counter. `None` (the default) disables the latch —
    /// an unbounded executor pool never reports pressure anyway.
    pub kv_pool: Option<Arc<crate::kernels::KvPagePool>>,
}

/// What one executor call produced: host seconds for the whole batch plus
/// one result per request, **in `batch.requests` order** — the model output
/// on success, this request's own error otherwise.
#[derive(Debug)]
pub struct BatchResult {
    pub host_s: f64,
    pub outputs: Vec<RequestResult>,
    /// Set by fault-injecting wrappers when this batch's measured time or
    /// results were perturbed (latency spike, overwritten result): the
    /// drift auditor must skip the batch — its wall time no longer means
    /// what the co-simulation predicts.
    pub faulted: bool,
}

/// The execution backend a worker invokes per batch. Implementations:
/// [`crate::kernels::NativeExecutor`] (native bit-packed GEMMs, sessions,
/// default) and the PJRT artifact path (wrapped in an [`FnExecutor`],
/// `--features pjrt`). Returns per-request results; `Err` means the whole
/// batch failed (e.g. unknown model) and every request inherits the error.
pub trait Executor: Send {
    fn execute(&mut self, batch: &Batch) -> Result<BatchResult, String>;

    /// Roll one session's KV state back to `tokens` committed tokens before
    /// a decode retry, so the re-executed step attends exactly the past a
    /// first attempt would have seen (the failed attempt may have appended
    /// rows before dying). Returns whether anything was rolled back; the
    /// default no-op suits stateless executors.
    fn rollback_session(&mut self, _session: u64, _tokens: usize) -> bool {
        false
    }

    /// Short backend name for logs/metrics.
    fn name(&self) -> &str {
        "executor"
    }
}

/// Adapter for closure-based executors (tests, stubs, the PJRT path whose
/// client must be constructed lazily inside the worker thread). The closure
/// keeps the original whole-batch signature — host seconds or one error —
/// and the adapter expands it to per-request results (`Ok` with an empty
/// output for every request). A blanket `impl Executor for F: FnMut` would
/// collide with concrete executor impls under coherence rules, hence the
/// explicit wrapper.
pub struct FnExecutor<F>(pub F);

impl<F> Executor for FnExecutor<F>
where
    F: FnMut(&Batch) -> Result<f64, String> + Send,
{
    fn execute(&mut self, batch: &Batch) -> Result<BatchResult, String> {
        let host_s = (self.0)(batch)?;
        Ok(BatchResult {
            host_s,
            outputs: batch.requests.iter().map(|_| Ok(Vec::new())).collect(),
            faulted: false,
        })
    }

    fn name(&self) -> &str {
        "fn"
    }
}

/// A single-worker serving loop (the accelerator is one device; batching,
/// not worker parallelism, is the throughput lever).
pub struct Server {
    batcher: Arc<Mutex<Batcher>>,
    metrics: Arc<Mutex<Metrics>>,
    stop: Arc<AtomicBool>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// Failed attempts waiting out their backoff: (due time, request with
    /// `attempt` bumped). The worker promotes due entries into the batcher;
    /// shutdown settles the rest like any other unserved request.
    retry_q: RetryQueue,
    resilience: Resilience,
    /// Budgeted KV pool being watched (see [`ServerConfig::kv_pool`]):
    /// kept so shutdown can take a final gauge sample after the worker
    /// stops sampling.
    kv_pool: Option<Arc<crate::kernels::KvPagePool>>,
}

/// The retry queue shared between [`Server`] and its worker.
type RetryQueue = Arc<Mutex<Vec<(Instant, Request)>>>;

impl Server {
    /// Start the worker with the given executor.
    pub fn start(cfg: ServerConfig, executor: Box<dyn Executor>) -> Self {
        let batcher = Arc::new(Mutex::new(Batcher::new(cfg.policy)));
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        // The drift gate lives inside the auditor so Metrics snapshots and
        // reports carry the bound they were judged against.
        metrics.lock().unwrap().drift.bound = cfg.drift.clone();
        let stop = Arc::new(AtomicBool::new(false));

        let retry_q: RetryQueue = Arc::new(Mutex::new(Vec::new()));
        let resilience = cfg.resilience.clone();
        let kv_pool = cfg.kv_pool.clone();

        let b = batcher.clone();
        let m = metrics.clone();
        let s = stop.clone();
        let rq = retry_q.clone();
        let accel = FlexiBitAccel::new();
        let mut executor = executor;
        let worker = std::thread::spawn(move || {
            // The whole serving loop runs with cfg.recorder installed as the
            // thread's current recorder, so batcher and kernel
            // instrumentation (obs::count and friends) lands in the same
            // sink as the request spans without any executor plumbing.
            let rec = cfg.recorder.clone();
            obs::with_current(&rec, || {
                // Committed tokens per live session (plus the policy its KV
                // is priced under), tracked from the request stream (prefill
                // row count, +1 per decode step) so all-decode batches
                // co-simulate against their sessions' actual cached past and
                // the co-sim can charge each session its paged KV footprint.
                // Entries are dropped on Phase::End; a session the executor
                // evicted leaves a stale entry behind until then.
                let mut session_tokens: SessionLedger = HashMap::new();
                // Hard allocation failures already acknowledged — only
                // *growth* of the pool's counter latches memory pressure.
                let mut seen_hard_failures = 0u64;
                while !s.load(Ordering::Relaxed) {
                    // Re-enqueue retry attempts whose backoff elapsed, and
                    // relax the Degraded flag once the queue drained below
                    // half its bound (hysteresis — no flapping at the edge).
                    Self::promote_due_retries(&rq, &b);
                    if cfg.resilience.queue_bound > 0 {
                        let pending = b.lock().unwrap().pending();
                        let mut met = m.lock().unwrap();
                        if met.degraded && pending * 2 < cfg.resilience.queue_bound {
                            met.degraded = false;
                        }
                    }
                    // Memory-pressure latch + pool gauge sampling: a hard
                    // allocation failure (budget exhausted and nothing left
                    // to preempt) flips the server into MemoryPressure so
                    // `submit` sheds new prefills with ERR_SHED_MEM; the
                    // latch clears only once pool usage drops below half
                    // the budget (hysteresis — a pool still nearly full
                    // would re-fail the very next prefill).
                    if let Some(pool) = &cfg.kv_pool {
                        let hard = pool.hard_failures();
                        let mut met = m.lock().unwrap();
                        met.sessions_preempted = pool.preemptions();
                        met.kv_pages_in_use = pool.pages_in_use() as u64;
                        met.kv_bytes_in_use = pool.bytes_in_use() as u64;
                        if hard > seen_hard_failures {
                            met.mem_pressure = true;
                        } else if met.mem_pressure
                            && pool.bytes_in_use().saturating_mul(2) < pool.budget_bytes()
                        {
                            met.mem_pressure = false;
                        }
                        seen_hard_failures = hard;
                    }
                    let maybe = { b.lock().unwrap().next_batch(Instant::now()) };
                    match maybe {
                        Some(mut batch) => {
                            // Deadline check at batch cut: expired requests
                            // resolve without executing.
                            Self::settle_expired(&mut batch, &m);
                            // When this batch (round) was formed — the end of
                            // each admitted request's queue-wait span.
                            let mut formed = Instant::now();
                            loop {
                                if !batch.requests.is_empty() {
                                    Self::run_batch(
                                        &batch,
                                        formed,
                                        &mut executor,
                                        &b,
                                        &m,
                                        &cfg,
                                        &accel,
                                        &mut session_tokens,
                                        &rq,
                                    );
                                }
                                if s.load(Ordering::Relaxed) {
                                    break;
                                }
                                // Continuous admission: decode steps of this hot key
                                // that arrived while the batch executed join
                                // immediately — no wait budget, no reconfiguration.
                                // The batcher counts each round toward the fairness
                                // streak and refuses once it is exhausted while
                                // other keys wait, so an endless token stream cannot
                                // starve them (and keeps its slot when uncontended).
                                let extra = b.lock().unwrap().admit_decode(
                                    &batch.model,
                                    &batch.policy,
                                    cfg.policy.max_batch,
                                );
                                if extra.is_empty() {
                                    break;
                                }
                                batch.requests = extra;
                                Self::settle_expired(&mut batch, &m);
                                formed = Instant::now();
                            }
                        }
                        None => std::thread::sleep(Duration::from_micros(200)),
                    }
                }
            });
        });
        Server { batcher, metrics, stop, worker: Some(worker), retry_q, resilience, kv_pool }
    }

    /// Execute one batch and settle it: fulfill every request's completion
    /// slot, tally per-request metrics, emit lifecycle spans, and keep
    /// `session_tokens` (the worker's committed-token ledger feeding decode
    /// co-simulation) current. `formed` is when this batch (round) was cut
    /// from the queue — the boundary between a request's queue-wait and
    /// execution spans.
    #[allow(clippy::too_many_arguments)]
    fn run_batch(
        batch: &Batch,
        formed: Instant,
        executor: &mut Box<dyn Executor>,
        b: &Arc<Mutex<Batcher>>,
        m: &Arc<Mutex<Metrics>>,
        cfg: &ServerConfig,
        accel: &FlexiBitAccel,
        session_tokens: &mut SessionLedger,
        retry_q: &RetryQueue,
    ) {
        let rec = &cfg.recorder;
        // Per-category span-duration snapshot: the executor runs on this
        // thread, and layer/gemm spans complete (and accumulate) on the
        // recording thread synchronously, so the delta across the call is
        // exactly this batch's recorded kernel/layer time.
        let (kernel0_s, model0_s) = (rec.span_dur_s("kernel"), rec.span_dur_s("model"));
        let t0 = Instant::now();
        // Panic isolation: a poisoned batch fails its own requests through
        // the same per-request plumbing a returned error uses — the worker
        // loop survives and keeps draining. AssertUnwindSafe is justified
        // because the executor is only ever touched again through &mut
        // calls that re-establish their own invariants (NativeExecutor's
        // state is per-session, and a retried decode rolls its session
        // back explicitly before re-executing).
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            executor.execute(batch)
        }));
        let executed = match caught {
            Ok(r) => r,
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| (*s).to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".into());
                obs::count(obs::Counter::PanicCaught);
                m.lock().unwrap().batches_panicked += 1;
                Err(format!("executor panicked: {msg}"))
            }
        };
        match executed {
            Err(e) => {
                // A failed batch completed nothing: it never counts as
                // executed, its requests stay out of completion/latency/
                // co-simulation stats (and out of the histograms and span
                // stream), and each request either re-enqueues under the
                // retry policy or reports the error to its submitter. End
                // requests still retire their ledger entry — the client is
                // done with the session whether or not the executor
                // acknowledged it — and are never retried (teardown is
                // idempotent and re-sent by nobody).
                eprintln!("executor '{}' failed on batch: {e}", executor.name());
                let mut met = m.lock().unwrap();
                met.batches_failed += 1;
                met.reconfigurations = b.lock().unwrap().reconfigurations;
                for r in &batch.requests {
                    if r.phase == Phase::End {
                        session_tokens.remove(&r.session);
                        if let Some(done) = &r.done {
                            done.fulfill(Err(e.clone()));
                        }
                        continue;
                    }
                    Self::fail_or_retry(
                        r,
                        e.clone(),
                        executor,
                        retry_q,
                        &mut met,
                        &cfg.resilience,
                        session_tokens,
                    );
                }
            }
            Ok(res) => {
                let done_at = Instant::now();
                let faulted = res.faulted;
                let mut outputs = res.outputs;
                // Defend the per-request contract: an executor that
                // returned too few results fails the unanswered tail.
                outputs.resize_with(batch.requests.len(), || {
                    Err("executor returned no result for this request".into())
                });
                // Co-simulation: the predicted accelerator cost of exactly
                // the work that succeeded, summed per request. A decode
                // step simulates at seq=1 against its session's cached past
                // (honest `1 × hd × (T+1)` GEMV attention shapes via the
                // ledger); a prefill simulates at its *actual* row count —
                // not the configured spec seq — so the predicted cost
                // scales with the batch's real token content the same way
                // the measured cost does (this is what makes the drift
                // ratio meaningful per shape class). End control requests
                // and failed requests predict 0: they execute no model
                // work / are excluded from every other stat too.
                let (mut sim_s, mut sim_j) = (0.0f64, 0.0f64);
                let (mut n_prefill, mut n_decode, mut n_failed) = (0u64, 0u64, 0u64);
                let mut tokens = 0u64;
                for (r, out) in batch.requests.iter().zip(outputs.iter()) {
                    if r.phase == Phase::End {
                        continue;
                    }
                    if out.is_err() {
                        n_failed += 1;
                        continue;
                    }
                    let (seq, past) = match r.phase {
                        Phase::Decode => {
                            (1, session_tokens.get(&r.session).map(|(t, _)| *t).unwrap_or(0))
                        }
                        _ => (prefill_rows(r, cfg.sim_model.d_model).max(1), 0),
                    };
                    let model = ModelSpec { seq, ..cfg.sim_model.clone() };
                    let rep = sim::simulate_model_policy(
                        accel,
                        &cfg.sim_config,
                        &model,
                        &batch.policy,
                        past,
                    );
                    sim_s += rep.seconds;
                    sim_j += rep.energy_j;
                    match r.phase {
                        Phase::Decode => {
                            n_decode += 1;
                            tokens += 1;
                        }
                        _ => {
                            n_prefill += 1;
                            tokens += seq as u64;
                        }
                    }
                }
                // Session-length ledger: prefill (re)starts a session at its
                // row count, each decode step commits one more token, End
                // retires the entry — mirroring the executor's KV cache.
                // Ends retire unconditionally (an abandoned session must not
                // leak its entry), decodes only advance sessions the ledger
                // knows (an unknown one simulates at past 0 and stays out),
                // and the map is hard-capped so a client that never sends
                // End cannot grow it without bound.
                for (r, out) in batch.requests.iter().zip(outputs.iter()) {
                    if r.phase == Phase::End {
                        session_tokens.remove(&r.session);
                        continue;
                    }
                    if out.is_err() {
                        continue;
                    }
                    match r.phase {
                        Phase::Prefill if r.session != 0 => {
                            if session_tokens.len() >= SESSION_LEDGER_CAP
                                && !session_tokens.contains_key(&r.session)
                            {
                                let victim = session_tokens.keys().next().copied();
                                if let Some(v) = victim {
                                    session_tokens.remove(&v);
                                }
                            }
                            session_tokens.insert(
                                r.session,
                                (
                                    prefill_rows(r, cfg.sim_model.d_model),
                                    Arc::clone(&batch.policy),
                                ),
                            );
                        }
                        Phase::Decode if r.session != 0 => {
                            if let Some((t, _)) = session_tokens.get_mut(&r.session) {
                                *t += 1;
                            }
                        }
                        _ => {}
                    }
                }
                // Per-session KV footprint: price every live ledger session's
                // paged KV under its own policy — the co-simulated companion
                // of the pool's measured `kv_bytes_in_use` gauge.
                let kv_sim: u64 = session_tokens
                    .values()
                    .map(|(t, p)| sim::kv_session_footprint(&cfg.sim_model, p, *t) as u64)
                    .sum();
                let host_s = res.host_s.max(done_at.duration_since(t0).as_secs_f64());
                let mut ok_in_batch = 0u64;
                let mut met = m.lock().unwrap();
                met.batches_executed += 1;
                met.host_exec_s += host_s;
                met.sim_accel_s += sim_s;
                met.sim_energy_j += sim_j;
                met.kv_bytes_simulated = kv_sim;
                for (r, out) in batch.requests.iter().zip(outputs) {
                    match &out {
                        // Session-end control messages are fulfilled but not
                        // counted — they are bookkeeping, not served work,
                        // and must not inflate completion/latency stats.
                        Ok(_) if r.phase == Phase::End => {}
                        Ok(_) => {
                            met.requests_completed += 1;
                            met.total_batch_size += 1;
                            ok_in_batch += 1;
                            if r.attempt > 0 {
                                met.retry_success += 1;
                            }
                            let lat = done_at.duration_since(r.arrived).as_secs_f64();
                            met.latency.record(lat);
                            match r.phase {
                                Phase::Prefill => {
                                    met.prefill_latency.record(lat);
                                    if r.session != 0 {
                                        met.sessions_started += 1;
                                    }
                                }
                                Phase::Decode => {
                                    met.decode_steps += 1;
                                    met.decode_latency.record(lat);
                                }
                                _ => {}
                            }
                            // Lifecycle spans mirror the scalar stats:
                            // successful requests only.
                            if rec.is_enabled() {
                                emit_request_spans(rec, r, formed, done_at);
                            }
                        }
                        // A non-End request that failed individually either
                        // re-enqueues under the retry policy (its slot stays
                        // open for the final attempt) or settles failed here;
                        // either way the common fulfill below is skipped.
                        Err(e) if r.phase != Phase::End => {
                            Self::fail_or_retry(
                                r,
                                e.clone(),
                                executor,
                                retry_q,
                                &mut met,
                                &cfg.resilience,
                                session_tokens,
                            );
                            continue;
                        }
                        Err(_) => met.requests_failed_exec += 1,
                    }
                    if let Some(done) = &r.done {
                        done.fulfill(out);
                    }
                }
                met.batch_size.record(ok_in_batch as f64);
                met.reconfigurations = b.lock().unwrap().reconfigurations;
                // Drift audit: exactly one entry — audited or skipped — per
                // executed batch. The dispatch kind partitions populations
                // whose host cost scales differently; a batch with any
                // failed request is skipped outright (its measured wall
                // covers work the co-sim excludes), a fault-perturbed batch
                // is skipped too (an injected latency spike would trip the
                // drift gate on time the model never spent), and End-only
                // batches skip via tokens == 0.
                let kind = match (n_prefill > 0, n_decode > 0) {
                    (true, false) => "prefill",
                    (false, true) => "decode",
                    (true, true) => "mixed",
                    (false, false) => "none",
                };
                let (gemm_s, layer_s) = (
                    (rec.span_dur_s("kernel") - kernel0_s).max(0.0),
                    (rec.span_dur_s("model") - model0_s).max(0.0),
                );
                met.drift.attribute(host_s, rec.is_enabled().then_some((gemm_s, layer_s)));
                let violation = if n_failed > 0 || faulted {
                    met.drift.note_skipped();
                    None
                } else {
                    met.drift.observe(batch.policy.label(), kind, tokens, host_s, sim_s)
                };
                drop(met);
                if let Some(v) = &violation {
                    eprintln!("{v} (model '{}')", batch.model);
                }
                // The batch span's duration is exactly the host seconds
                // credited to host_exec_s, so the trace's batch.execute
                // spans sum to the metric; the per-batch utilization split
                // (child-span deltas) rides along as args.
                if rec.is_enabled() {
                    rec.span(SpanEvent {
                        name: "batch.execute",
                        cat: "serve",
                        ts_us: rec.us_since_epoch(t0),
                        dur_us: host_s * 1e6,
                        pid: PID_EXEC,
                        tid: obs::thread_tid(),
                        args: vec![
                            ("model", batch.model.as_str().into()),
                            ("pair", batch.policy.label().to_string().into()),
                            ("requests", batch.requests.len().into()),
                            ("completed", ok_in_batch.into()),
                            ("kind", kind.into()),
                            ("tokens", tokens.into()),
                            ("sim_s", sim_s.into()),
                            ("gemm_s", gemm_s.into()),
                            ("layer_s", layer_s.into()),
                        ],
                    });
                }
            }
        }
    }

    /// Route one failed non-End request: re-enqueue it for another attempt
    /// if the retry budget allows, else settle it failed. Before a decode
    /// retry the executor rolls the session's KV back to the ledger's
    /// committed token count — failed outputs never advanced the ledger, so
    /// it holds exactly the pre-batch state and the retried step re-executes
    /// bit-identically to a first attempt. (A decode whose session fell out
    /// of the capped ledger skips the rollback and relies on the executor
    /// rejecting the stale stream.) The caller holds the metrics lock;
    /// `retry_q` is locked strictly after it, matching `promote_due_retries`
    /// which holds neither while locking the batcher.
    fn fail_or_retry(
        r: &Request,
        err: String,
        executor: &mut Box<dyn Executor>,
        retry_q: &RetryQueue,
        met: &mut Metrics,
        res: &Resilience,
        session_tokens: &SessionLedger,
    ) {
        if r.attempt < res.max_retries {
            let rollback_to = match r.phase {
                Phase::Decode => session_tokens.get(&r.session).map(|(t, _)| *t),
                _ => None,
            };
            if let Some(committed) = rollback_to {
                executor.rollback_session(r.session, committed);
            }
            let backoff = res.retry_backoff.saturating_mul(1u32 << r.attempt.min(20));
            met.retries += 1;
            met.retry_backoff.record(backoff.as_secs_f64());
            let mut again = r.clone();
            again.attempt += 1;
            retry_q.lock().unwrap().push((Instant::now() + backoff, again));
            return;
        }
        met.requests_failed_exec += 1;
        eprintln!("request {} failed after {} attempts: {err}", r.id, r.attempt + 1);
        if let Some(done) = &r.done {
            done.fulfill(Err(err));
        }
    }

    /// Move retry attempts whose backoff elapsed back into the batcher,
    /// preserving enqueue order among the due. The retry queue's lock is
    /// released before the batcher's is taken.
    fn promote_due_retries(retry_q: &RetryQueue, b: &Arc<Mutex<Batcher>>) {
        let now = Instant::now();
        let due: Vec<Request> = {
            let mut q = retry_q.lock().unwrap();
            if q.iter().all(|(at, _)| *at > now) {
                return;
            }
            let (ready, later): (Vec<_>, Vec<_>) = q.drain(..).partition(|(at, _)| *at <= now);
            *q = later;
            ready.into_iter().map(|(_, r)| r).collect()
        };
        let mut batcher = b.lock().unwrap();
        for r in due {
            batcher.push(r);
        }
    }

    /// Deadline check at batch cut: requests past their deadline resolve
    /// `Err` without executing and leave the batch. End control requests
    /// are exempt — session teardown must run no matter how late.
    fn settle_expired(batch: &mut Batch, m: &Arc<Mutex<Metrics>>) {
        let now = Instant::now();
        let (kept, expired): (Vec<_>, Vec<_>) = std::mem::take(&mut batch.requests)
            .into_iter()
            .partition(|r| r.phase == Phase::End || r.deadline.is_none_or(|d| now < d));
        batch.requests = kept;
        if expired.is_empty() {
            return;
        }
        m.lock().unwrap().requests_failed_deadline += expired.len() as u64;
        for r in expired {
            if let Some(done) = &r.done {
                done.fulfill(Err(ERR_DEADLINE.into()));
            }
        }
    }

    /// Enqueue a request, stamping the server's default deadline if the
    /// request carries none. Returns `false` when admission control shed it:
    /// while the server is under memory pressure, new prefills resolve
    /// [`ERR_SHED_MEM`] immediately (admitting one would only force another
    /// preemption or hard failure); with a nonzero
    /// [`Resilience::queue_bound`], new prefills are rejected once the
    /// queue is that deep — their completion resolves [`ERR_SHED`]
    /// immediately and the server flips to Degraded. Decode and End
    /// requests of in-flight sessions are always admitted under both
    /// policies (a stream already holding KV residency must be able to
    /// finish — or, if preempted, to re-prefill within its own budget
    /// share).
    pub fn submit(&self, mut req: Request) -> bool {
        if req.deadline.is_none() {
            if let Some(budget) = self.resilience.default_deadline {
                req.deadline = Some(req.arrived + budget);
            }
        }
        if req.phase == Phase::Prefill {
            let mut met = self.metrics.lock().unwrap();
            if met.mem_pressure {
                met.requests_shed_mem += 1;
                drop(met);
                if let Some(done) = &req.done {
                    done.fulfill(Err(ERR_SHED_MEM.into()));
                }
                return false;
            }
        }
        let bound = self.resilience.queue_bound;
        if bound > 0
            && req.phase == Phase::Prefill
            && self.batcher.lock().unwrap().pending() >= bound
        {
            {
                let mut met = self.metrics.lock().unwrap();
                met.requests_shed += 1;
                met.degraded = true;
            }
            if let Some(done) = &req.done {
                done.fulfill(Err(ERR_SHED.into()));
            }
            return false;
        }
        self.batcher.lock().unwrap().push(req);
        true
    }

    pub fn pending(&self) -> usize {
        self.batcher.lock().unwrap().pending()
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Block until at least `n` requests have completed or `timeout`
    /// elapses; returns whether the target was reached. The standard drain
    /// step between submitting a stream and calling [`Server::shutdown`].
    pub fn await_completed(&self, n: u64, timeout: Duration) -> bool {
        self.await_count(n, timeout, |m| m.requests_completed)
    }

    /// Like [`Server::await_completed`] but counts failed requests too —
    /// use to drain streams where some batches are expected to error.
    pub fn await_finished(&self, n: u64, timeout: Duration) -> bool {
        self.await_count(n, timeout, |m| m.requests_finished())
    }

    fn await_count(&self, n: u64, timeout: Duration, count: impl Fn(&Metrics) -> u64) -> bool {
        let deadline = Instant::now() + timeout;
        while count(&self.metrics()) < n {
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        true
    }

    /// Stop the worker and return final metrics. Requests still queued are
    /// settled first: their completions resolve to an error and they count
    /// as failed (`Phase::End` control requests are dropped silently).
    pub fn shutdown(mut self) -> Metrics {
        self.stop_and_settle();
        let m = self.metrics.lock().unwrap().clone();
        m
    }

    fn stop_and_settle(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        // Final pool sample: the worker's last iteration may predate the
        // executor's last allocation/preemption, and shutdown reports must
        // carry the settled counts.
        if let Some(pool) = &self.kv_pool {
            let mut met = self.metrics.lock().unwrap();
            met.sessions_preempted = pool.preemptions();
            met.kv_pages_in_use = pool.pages_in_use() as u64;
            met.kv_bytes_in_use = pool.bytes_in_use() as u64;
        }
        self.settle_unserved();
    }

    /// Settle every request the stopped worker will never execute: fulfill
    /// its completion with an error (a submitter blocked in `wait` must not
    /// spin out its timeout) and count it failed. [`Phase::End`] control
    /// requests are the exception — they are dropped silently, since server
    /// shutdown tears every session down anyway.
    fn settle_unserved(&self) {
        let mut unserved = self.batcher.lock().unwrap().drain();
        // Retry-pending requests are queued work too: an attempt waiting out
        // its backoff when the server stops settles as a shutdown failure
        // exactly like one still in the batcher.
        unserved.extend(self.retry_q.lock().unwrap().drain(..).map(|(_, r)| r));
        if unserved.is_empty() {
            return;
        }
        let mut failed = 0u64;
        for r in &unserved {
            if r.phase == Phase::End {
                continue;
            }
            failed += 1;
            if let Some(done) = &r.done {
                done.fulfill(Err("server shut down before executing this request".into()));
            }
        }
        self.metrics.lock().unwrap().requests_failed_shutdown += failed;
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_and_settle();
    }
}

/// Upper bound on tracked co-sim ledger sessions — mirrors the executor's
/// own session capacity bound (`kernels::DEFAULT_SESSION_CAPACITY` scale):
/// sessions beyond it lose their past-length estimate (they co-simulate at
/// past 0), never memory.
const SESSION_LEDGER_CAP: usize = 4096;

/// The worker's per-session co-sim ledger: committed token count plus the
/// policy that session's KV is priced under (set at prefill — the phase
/// that opens the KV cache — and carried unchanged through decode).
type SessionLedger = HashMap<u64, (usize, Arc<PrecisionPolicy>)>;

/// Committed tokens a session prefill contributes to the co-sim ledger:
/// the leading dim of a 2-D request shape, else inferred from the co-sim
/// model's width.
fn prefill_rows(r: &Request, d_model: usize) -> usize {
    match r.dims.as_slice() {
        [rows, _] => *rows,
        _ if d_model > 0 => r.input.len() / d_model,
        _ => 0,
    }
}

fn phase_name(p: Phase) -> &'static str {
    match p {
        Phase::Prefill => "prefill",
        Phase::Decode => "decode",
        Phase::End => "end",
    }
}

/// Emit one successful request's lifecycle spans on the request track
/// (pid [`PID_REQUEST`], tid = request id): the enclosing `request` span
/// (arrival → completion) plus its `request.queue` (arrival → batch
/// admission) and `request.exec` (admission → completion) phases, so
/// queue-wait/batch-formation time reads directly off the trace.
fn emit_request_spans(rec: &Recorder, r: &Request, formed: Instant, done_at: Instant) {
    let arrived_us = rec.us_since_epoch(r.arrived);
    let formed_us = rec.us_since_epoch(formed).max(arrived_us);
    let done_us = rec.us_since_epoch(done_at).max(formed_us);
    let phase = phase_name(r.phase);
    rec.span(SpanEvent {
        name: "request",
        cat: "serve",
        ts_us: arrived_us,
        dur_us: done_us - arrived_us,
        pid: PID_REQUEST,
        tid: r.id,
        args: vec![
            ("id", r.id.into()),
            ("session", r.session.into()),
            ("phase", phase.into()),
            ("model", r.model.as_str().into()),
            ("pair", r.policy.label().to_string().into()),
        ],
    });
    rec.span(SpanEvent {
        name: "request.queue",
        cat: "serve",
        ts_us: arrived_us,
        dur_us: formed_us - arrived_us,
        pid: PID_REQUEST,
        tid: r.id,
        args: vec![("phase", phase.into())],
    });
    rec.span(SpanEvent {
        name: "request.exec",
        cat: "serve",
        ts_us: formed_us,
        dur_us: done_us - formed_us,
        pid: PID_REQUEST,
        tid: r.id,
        args: Vec::new(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Completion;
    use crate::workload::{bert_base, PrecisionPair};

    fn tiny_model() -> ModelSpec {
        ModelSpec {
            seq: 8,
            layers: 1,
            d_model: 32,
            d_ff: 64,
            heads: 2,
            gated_ffn: false,
            kv_heads: 2,
            name: "tiny",
        }
    }

    fn mk_req(id: u64, bits: u32) -> Request {
        Request::new(id, "tiny", PrecisionPair::of_bits(bits, 16), vec![1.0; 8], vec![8])
    }

    fn stub_cfg(max_batch: usize, max_streak: usize) -> ServerConfig {
        ServerConfig {
            policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(1), max_streak },
            sim_config: crate::sim::mobile_a(),
            sim_model: tiny_model(),
            recorder: Recorder::disabled(),
            drift: None,
            resilience: Resilience::default(),
            kv_pool: None,
        }
    }

    #[test]
    fn serves_requests_through_stub_executor() {
        let server = Server::start(
            stub_cfg(4, 4),
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        for i in 0..16 {
            server.submit(mk_req(i, 6));
        }
        assert!(server.await_completed(16, Duration::from_secs(5)), "stream must drain");
        let m = server.shutdown();
        assert_eq!(m.requests_completed, 16);
        assert!(m.batches_executed >= 4, "batched into >= 4 batches");
        assert!(m.mean_batch_size() >= 1.0);
        assert!(m.sim_accel_s > 0.0);
        assert!(m.sim_energy_j > 0.0);
    }

    #[test]
    fn mixed_precision_serving_counts_reconfigs() {
        let server = Server::start(
            stub_cfg(2, 2),
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        for i in 0..8 {
            server.submit(mk_req(i, if i % 2 == 0 { 6 } else { 8 }));
        }
        assert!(server.await_completed(8, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.requests_completed, 8);
        assert!(m.reconfigurations >= 1, "precision switching must be counted");
    }

    #[test]
    fn failing_executor_counts_failures_not_completions() {
        // Executor fails every FP6 batch; half the stream is FP6.
        let server = Server::start(
            stub_cfg(4, 4),
            Box::new(FnExecutor(|b: &Batch| -> Result<f64, String> {
                if b.policy.head_pair().w.bits() == 6 {
                    Err("synthetic executor failure".into())
                } else {
                    Ok(0.0)
                }
            })),
        );
        let mut slots = Vec::new();
        for i in 0..12 {
            let done = Completion::new();
            let bits = if i % 2 == 0 { 6 } else { 8 };
            server.submit(mk_req(i, bits).with_completion(&done));
            slots.push((bits, done));
        }
        assert!(server.await_finished(12, Duration::from_secs(5)), "stream must drain");
        let m = server.shutdown();
        assert_eq!(m.requests_failed(), 6, "failed batches count as failed");
        assert_eq!(m.requests_failed_exec, 6, "all failures are executor failures");
        assert_eq!(m.requests_failed_shutdown, 0);
        assert_eq!(m.requests_completed, 6, "successes still complete");
        assert!(m.batches_failed >= 1);
        assert_eq!(m.requests_finished(), 12);
        // Failed batches contribute no latency or batch-size stats.
        assert_eq!(m.total_batch_size, m.requests_completed);
        // Per-request plumbing: every submitter learns its own fate, and a
        // whole-batch failure propagates the executor's error verbatim.
        for (bits, done) in &slots {
            let got = done.poll().expect("every request must resolve");
            if *bits == 6 {
                assert_eq!(got.unwrap_err(), "synthetic executor failure");
            } else {
                assert!(got.is_ok());
            }
        }
    }

    /// An executor that completes some requests and fails others *within
    /// one batch* — the submitter of the dead request (and only that one)
    /// must see its error.
    struct PartialExec;
    impl Executor for PartialExec {
        fn execute(&mut self, batch: &Batch) -> Result<BatchResult, String> {
            let outputs = batch
                .requests
                .iter()
                .map(|r| {
                    if r.id % 3 == 0 {
                        Err(format!("request {} rejected", r.id))
                    } else {
                        Ok(vec![r.id as f32])
                    }
                })
                .collect();
            Ok(BatchResult { host_s: 0.0, outputs, faulted: false })
        }
        fn name(&self) -> &str {
            "partial"
        }
    }

    #[test]
    fn partially_failing_batch_reports_per_request() {
        let server = Server::start(stub_cfg(4, 4), Box::new(PartialExec));
        let mut slots = Vec::new();
        for i in 0..12 {
            let done = Completion::new();
            server.submit(mk_req(i, 6).with_completion(&done));
            slots.push(done);
        }
        assert!(server.await_finished(12, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.requests_failed(), 4, "ids 0,3,6,9 fail");
        assert_eq!(m.requests_completed, 8);
        assert_eq!(m.batches_failed, 0, "a partial failure is not a batch failure");
        assert_eq!(m.total_batch_size, m.requests_completed);
        // The histograms track the scalar counters exactly, failed slots
        // excluded: only the 8 completed requests have latencies, and the
        // batch-size distribution integrates to (size, count).
        assert_eq!(m.latency.count(), m.requests_completed);
        assert_eq!(m.batch_size.count(), m.batches_executed);
        assert_eq!(m.batch_size.sum(), m.total_batch_size as f64);
        for (i, done) in slots.iter().enumerate() {
            let got = done.poll().expect("resolved");
            if i % 3 == 0 {
                assert_eq!(got.unwrap_err(), format!("request {i} rejected"));
            } else {
                assert_eq!(got.unwrap(), vec![i as f32], "output routed to its submitter");
            }
        }
    }

    /// All-decode batches co-simulate against the session's actual cached
    /// past: more prefilled context (and growing step count) must cost more
    /// simulated accelerator time for the same number of decode steps.
    #[test]
    fn decode_cosim_scales_with_cached_past() {
        let run = |prefill_rows: usize| -> f64 {
            let server = Server::start(
                stub_cfg(4, 4),
                Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
            );
            let d = tiny_model().d_model;
            let pair = PrecisionPair::of_bits(6, 16);
            server.submit(
                Request::new(0, "tiny", pair, vec![0.1; prefill_rows * d], vec![prefill_rows, d])
                    .with_session(1, Phase::Prefill),
            );
            assert!(server.await_completed(1, Duration::from_secs(5)));
            // One decode per batch (await between submits), so each step's
            // co-sim sees the ledger advanced by its predecessors.
            for i in 0..4u64 {
                server.submit(
                    Request::new(1 + i, "tiny", pair, vec![0.1; d], vec![d])
                        .with_session(1, Phase::Decode),
                );
                assert!(server.await_completed(2 + i, Duration::from_secs(5)));
            }
            let m = server.shutdown();
            assert_eq!(m.decode_steps, 4);
            m.sim_accel_s
        };
        let long = run(32);
        let short = run(1);
        assert!(
            long > short,
            "decode co-sim must grow with the cached past: {long} vs {short}"
        );
    }

    /// The worker prices every live ledger session's paged KV into the
    /// `kv_bytes_simulated` gauge (via `sim::kv_session_footprint`, under
    /// the session's own policy) and retires it when the session Ends.
    #[test]
    fn cosim_prices_per_session_kv_footprint() {
        use crate::workload::IntoPolicy;
        let server = Server::start(
            stub_cfg(4, 4),
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        let d = tiny_model().d_model;
        let pair = PrecisionPair::of_bits(6, 16);
        server.submit(
            Request::new(1, "tiny", pair, vec![0.1; 3 * d], vec![3, d])
                .with_session(1, Phase::Prefill),
        );
        assert!(server.await_completed(1, Duration::from_secs(5)));
        let expected =
            crate::sim::kv_session_footprint(&tiny_model(), &pair.into_policy(), 3) as u64;
        assert!(expected > 0);
        assert_eq!(server.metrics().kv_bytes_simulated, expected);
        // End retires the ledger entry; the next executed batch re-prices
        // the (now empty) ledger and the gauge returns to zero.
        server.submit(mk_req(2, 6).with_session(1, Phase::End));
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().kv_bytes_simulated != 0 {
            assert!(Instant::now() < deadline, "End must retire the session's footprint");
            std::thread::sleep(Duration::from_millis(1));
        }
        server.shutdown();
    }

    #[test]
    fn session_phases_are_tallied() {
        let server = Server::start(
            stub_cfg(4, 4),
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        server.submit(mk_req(0, 6).with_session(1, Phase::Prefill));
        for i in 1..5 {
            server.submit(mk_req(i, 6).with_session(1, Phase::Decode));
        }
        server.submit(mk_req(9, 6)); // stateless
        assert!(server.await_completed(6, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.sessions_started, 1);
        assert_eq!(m.decode_steps, 4);
        assert_eq!(m.requests_completed, 6);
    }

    #[test]
    fn metrics_math() {
        let mut m = Metrics {
            requests_completed: 10,
            batches_executed: 5,
            total_batch_size: 10,
            ..Metrics::default()
        };
        for _ in 0..10 {
            m.latency.record(0.5);
        }
        assert_eq!(m.mean_latency_s(), 0.5);
        assert_eq!(m.mean_batch_size(), 2.0);
        assert_eq!(m.throughput_rps(2.0), 5.0);
        // p50/p99 and the max come from the histogram now; a constant
        // series pins all of them to the exact observed value.
        assert_eq!(m.latency_max_s(), 0.5);
        assert_eq!(m.latency_p(0.5), 0.5);
        assert_eq!(m.latency_p(0.99), 0.5);
        // Every ratio funnels through one zero-denominator guard.
        let z = Metrics::default();
        assert_eq!(z.mean_latency_s(), 0.0);
        assert_eq!(z.mean_batch_size(), 0.0);
        assert_eq!(z.throughput_rps(0.0), 0.0);
        assert_eq!(z.throughput_rps(-1.0), 0.0);
        assert_eq!(z.latency_max_s(), 0.0);
        assert_eq!(z.latency_p(0.99), 0.0);
        // Avoid unused import warning for bert_base.
        let _ = bert_base();
    }

    /// Extends `failing_executor_counts_failures_not_completions` to the
    /// observability layer: histograms and the span stream must exclude
    /// failed requests exactly as the scalar counters do.
    #[test]
    fn failed_requests_stay_out_of_histograms_and_spans() {
        let rec = Recorder::enabled();
        let cfg = ServerConfig { recorder: rec.clone(), ..stub_cfg(4, 4) };
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|b: &Batch| -> Result<f64, String> {
                if b.policy.head_pair().w.bits() == 6 {
                    Err("synthetic executor failure".into())
                } else {
                    Ok(0.0)
                }
            })),
        );
        // Even ids are FP6 (every one fails), odd ids are FP8 (all succeed).
        for i in 0..12 {
            server.submit(mk_req(i, if i % 2 == 0 { 6 } else { 8 }));
        }
        assert!(server.await_finished(12, Duration::from_secs(5)), "stream must drain");
        let m = server.shutdown();
        assert_eq!(m.requests_completed, 6);
        assert_eq!(m.requests_failed_exec, 6);
        assert_eq!(m.requests_failed_shutdown, 0);
        // Histograms mirror the scalar counters exactly.
        assert_eq!(m.latency.count(), m.requests_completed);
        assert_eq!(m.batch_size.count(), m.batches_executed);
        assert_eq!(m.batch_size.sum(), m.total_batch_size as f64);
        // Span stream: lifecycle spans exist only for successful requests
        // (tid = request id; the failed ones are the even ids).
        let evs = rec.events();
        let req: Vec<_> = evs.iter().filter(|e| e.name == "request").collect();
        assert_eq!(req.len() as u64, m.requests_completed);
        assert!(req.iter().all(|e| e.tid % 2 == 1), "no spans for failed (even-id) requests");
        assert_eq!(evs.iter().filter(|e| e.name == "request.queue").count(), req.len());
        assert_eq!(evs.iter().filter(|e| e.name == "request.exec").count(), req.len());
        // batch.execute spans exist only for executed batches and their
        // durations sum to exactly the host_exec_s metric.
        let execs: Vec<_> = evs.iter().filter(|e| e.name == "batch.execute").collect();
        assert_eq!(execs.len() as u64, m.batches_executed);
        let span_sum_s = execs.iter().map(|e| e.dur_us).sum::<f64>() / 1e6;
        assert!(
            (span_sum_s - m.host_exec_s).abs() <= 1e-9 * (1.0 + m.host_exec_s),
            "batch.execute spans ({span_sum_s}) must sum to host_exec_s ({})",
            m.host_exec_s
        );
        assert_eq!(rec.dropped_events(), 0);
    }

    /// Requests still queued at shutdown settle as shutdown failures — a
    /// separate counter from executor failures.
    #[test]
    fn shutdown_settles_queued_requests_as_shutdown_failures() {
        let mut cfg = stub_cfg(8, 4);
        // A wait budget far beyond the test body: nothing gets executed.
        cfg.policy.max_wait = Duration::from_secs(30);
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        let done = Completion::new();
        server.submit(mk_req(0, 6).with_completion(&done));
        server.submit(mk_req(1, 6));
        let m = server.shutdown();
        assert_eq!(m.requests_completed, 0);
        assert_eq!(m.requests_failed_shutdown, 2);
        assert_eq!(m.requests_failed_exec, 0);
        assert_eq!(m.requests_failed(), 2);
        assert_eq!(m.requests_finished(), 2);
        let got = done.poll().expect("settled at shutdown");
        assert!(got.unwrap_err().contains("shut down"));
    }

    #[test]
    fn exporters_render_summary_and_prometheus() {
        let mut m = Metrics {
            requests_completed: 3,
            batches_executed: 2,
            total_batch_size: 3,
            decode_steps: 1,
            host_exec_s: 0.25,
            ..Metrics::default()
        };
        for v in [1e-3, 2e-3, 4e-3] {
            m.latency.record(v);
        }
        m.decode_latency.record(2e-3);
        m.batch_size.record(1.0);
        m.batch_size.record(2.0);

        let s = m.summary(0.5);
        assert!(s.contains("3 completed"), "summary: {s}");
        assert!(s.contains("p50") && s.contains("p99"));
        assert!(s.contains("decode:"), "decode line present when steps > 0");

        m.retries = 2;
        m.retry_success = 1;
        m.requests_shed = 1;
        m.degraded = true;
        m.requests_shed_mem = 2;
        m.sessions_preempted = 1;
        m.kv_pages_in_use = 7;
        m.kv_bytes_in_use = 7 * 2048;
        m.kv_bytes_simulated = 9 * 2048;

        // The faults line splits queue shedding from memory shedding, and
        // the kv line surfaces residency + preemptions.
        let s = m.summary(0.5);
        assert!(s.contains("1 shed (+2 mem)"), "summary: {s}");
        assert!(s.contains("7 pages resident (14 KiB), 1 sessions preempted"), "summary: {s}");

        let rec = Recorder::enabled();
        rec.count(obs::Counter::KvRepack);
        let p = m.prometheus_text(&rec, 0.5);
        assert!(p.contains("flexibit_requests_completed 3"));
        assert!(p.contains("flexibit_retries 2"));
        assert!(p.contains("flexibit_requests_shed 1"));
        assert!(p.contains("flexibit_requests_shed_mem 2"));
        assert!(p.contains("flexibit_sessions_preempted 1"));
        assert!(p.contains("flexibit_degraded 1"));
        assert!(p.contains("flexibit_memory_pressure 0"));
        assert!(p.contains("flexibit_kv_pages_in_use 7"));
        assert!(p.contains("flexibit_kv_bytes_simulated 18432"));
        assert!(p.contains("# TYPE flexibit_retry_backoff_seconds histogram"));
        // Real cumulative-bucket histograms plus quantile gauges.
        assert!(p.contains("# TYPE flexibit_request_latency_seconds histogram"));
        assert!(p.contains("flexibit_request_latency_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(p.contains("flexibit_request_latency_seconds_p99 "));
        assert!(p.contains("flexibit_request_latency_seconds_count 3"));
        assert!(p.contains("flexibit_prefill_latency_seconds_count 0"));
        assert!(p.contains("flexibit_batch_size_sum 3"));
        assert!(p.contains("flexibit_drift_audited_batches 0"));
        assert!(p.contains("flexibit_kv_repack_total 1"));
        // A disabled recorder keeps the scrape shape, all kernel counters 0.
        let p0 = m.prometheus_text(&Recorder::disabled(), 0.5);
        assert!(p0.contains("flexibit_kv_repack_total 0"));
        assert_eq!(p0.lines().count(), p.lines().count());

        // The machine-readable report carries the same numbers and is
        // parseable by the dumbest possible check: balanced and keyed.
        let j = m.report_json(0.5);
        assert!(j.starts_with("{\"schema\":\"flexibit.metrics.v4\","));
        assert!(j.contains("\"completed\":3"));
        assert!(j.contains("\"phases\":{\"all\":{\"count\":3"));
        assert!(j.contains("\"robustness\":{\"retries\":2,\"retry_success\":1,"));
        assert!(j.contains("\"requests_shed_mem\":2"));
        assert!(j.contains("\"degraded\":true"));
        assert!(j.contains("\"memory_pressure\":false"));
        assert!(j.contains("\"sessions_preempted\":1"));
        assert!(j.contains("\"kv_pages_in_use\":7"));
        assert!(j.contains("\"kv_bytes_simulated\":18432"));
        assert!(j.contains("\"drift\":{"));
        assert_eq!(
            j.matches('{').count(),
            j.matches('}').count(),
            "balanced braces: {j}"
        );
    }

    /// The drift auditor joins every executed batch with its co-simulated
    /// cost: audited + skipped must equal batches_executed, and a batch
    /// with a failed slot is skipped (its measured time covers work the
    /// co-sim excludes).
    #[test]
    fn drift_audit_covers_every_executed_batch() {
        let server = Server::start(
            stub_cfg(4, 4),
            // Nonzero, token-proportional measured time so ratios are
            // well-defined.
            Box::new(FnExecutor(|b: &Batch| -> Result<f64, String> {
                Ok(1e-5 * b.requests.len() as f64)
            })),
        );
        for i in 0..16 {
            server.submit(mk_req(i, if i % 2 == 0 { 6 } else { 8 }));
        }
        assert!(server.await_completed(16, Duration::from_secs(5)));
        let m = server.shutdown();
        assert!(m.drift.audited() > 0, "drift histogram must be populated");
        assert_eq!(
            m.drift.audited() + m.drift.skipped(),
            m.batches_executed,
            "one drift entry (or explicit skip) per executed batch"
        );
        assert_eq!(m.drift.total_samples(), m.drift.audited());
        assert_eq!(m.drift.violations(), 0, "no bound configured");
        let report = m.drift_report();
        assert!(report.contains("\"schema\":\"flexibit.drift.v1\""));
        assert!(report.contains("\"kind\":\"prefill\""));
    }

    /// Batches containing a failed request are skipped, not audited.
    #[test]
    fn drift_audit_skips_partially_failed_batches() {
        let server = Server::start(stub_cfg(4, 4), Box::new(PartialExec));
        for i in 0..12 {
            server.submit(mk_req(i, 6));
        }
        assert!(server.await_finished(12, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.drift.audited() + m.drift.skipped(), m.batches_executed);
        assert!(m.drift.skipped() > 0, "ids 0,3,6,9 fail, so some batch skipped");
    }

    /// An absurdly tight absolute band trips the gate on real traffic; the
    /// violation is counted and described, and serving itself continues.
    #[test]
    fn drift_gate_trips_on_impossible_band() {
        let mut cfg = stub_cfg(4, 4);
        // measured/predicted can never land inside [1e17, 2e17].
        cfg.drift =
            Some(DriftBound { band: Some((1e17, 2e17)), max_spread: None, warmup: 0 });
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|b: &Batch| -> Result<f64, String> {
                Ok(1e-5 * b.requests.len() as f64)
            })),
        );
        for i in 0..8 {
            server.submit(mk_req(i, 6));
        }
        assert!(server.await_completed(8, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.requests_completed, 8, "gate reports, it does not drop traffic");
        assert!(m.drift.violations() > 0, "impossible band must trip");
        assert!(m.drift.last_violation().is_some());
    }

    /// Fails every request's first attempt with a transient error; retried
    /// attempts succeed. Exercises the retry path end to end.
    struct FlakyExec;
    impl Executor for FlakyExec {
        fn execute(&mut self, batch: &Batch) -> Result<BatchResult, String> {
            let outputs = batch
                .requests
                .iter()
                .map(|r| {
                    if r.phase != Phase::End && r.attempt == 0 {
                        Err("transient fault".into())
                    } else {
                        Ok(vec![r.id as f32])
                    }
                })
                .collect();
            Ok(BatchResult { host_s: 0.0, outputs, faulted: false })
        }
        fn name(&self) -> &str {
            "flaky"
        }
    }

    /// Retried-then-succeeded requests resolve exactly once, with the final
    /// attempt's result — the submitter never sees the transient error
    /// (completion slots are write-once, and a retried attempt leaves the
    /// slot open for the attempt that settles it).
    #[test]
    fn retried_requests_resolve_exactly_once_with_final_result() {
        let mut cfg = stub_cfg(4, 4);
        cfg.resilience = Resilience {
            max_retries: 3,
            retry_backoff: Duration::from_micros(100),
            ..Resilience::default()
        };
        let server = Server::start(cfg, Box::new(FlakyExec));
        let mut slots = Vec::new();
        for i in 0..8 {
            let done = Completion::new();
            server.submit(mk_req(i, 6).with_completion(&done));
            slots.push(done);
        }
        assert!(server.await_completed(8, Duration::from_secs(5)), "retries must drain");
        let m = server.shutdown();
        assert_eq!(m.requests_completed, 8);
        assert_eq!(m.requests_failed_exec, 0, "every failure recovered on retry");
        assert_eq!(m.retries, 8, "each request retried exactly once");
        assert_eq!(m.retry_success, 8);
        assert_eq!(m.retry_backoff.count(), m.retries);
        for (i, done) in slots.iter().enumerate() {
            let got = done.poll().expect("resolved exactly once");
            assert_eq!(got.unwrap(), vec![i as f32], "final attempt's output, not the fault");
        }
    }

    /// A retry budget that runs out settles the request with the last error
    /// — bounded, never infinite.
    #[test]
    fn exhausted_retries_settle_failed() {
        let mut cfg = stub_cfg(4, 4);
        cfg.resilience = Resilience {
            max_retries: 2,
            retry_backoff: Duration::from_micros(100),
            ..Resilience::default()
        };
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> {
                Err("permanently down".into())
            })),
        );
        let done = Completion::new();
        server.submit(mk_req(1, 6).with_completion(&done));
        assert!(server.await_finished(1, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.requests_failed_exec, 1);
        assert_eq!(m.retries, 2, "exactly max_retries re-attempts");
        assert_eq!(m.retry_success, 0);
        assert!(done.poll().expect("settled").unwrap_err().contains("permanently down"));
    }

    /// A panicking executor fails its own batch and the worker survives to
    /// serve the rest of the stream.
    #[test]
    fn executor_panic_fails_batch_but_worker_survives() {
        let server = Server::start(
            stub_cfg(4, 4),
            Box::new(FnExecutor(|b: &Batch| -> Result<f64, String> {
                if b.policy.head_pair().w.bits() == 6 {
                    panic!("poisoned batch");
                }
                Ok(0.0)
            })),
        );
        let mut slots = Vec::new();
        for i in 0..12 {
            let done = Completion::new();
            let bits = if i % 2 == 0 { 6 } else { 8 };
            server.submit(mk_req(i, bits).with_completion(&done));
            slots.push((bits, done));
        }
        assert!(server.await_finished(12, Duration::from_secs(5)), "worker must survive");
        let m = server.shutdown();
        assert!(m.batches_panicked >= 1);
        assert_eq!(m.requests_completed, 6, "the FP8 half still serves");
        assert_eq!(m.requests_failed_exec, 6);
        for (bits, done) in &slots {
            let got = done.poll().expect("resolved");
            if *bits == 6 {
                let err = got.unwrap_err();
                assert!(err.contains("panicked") && err.contains("poisoned batch"), "{err}");
            } else {
                assert!(got.is_ok());
            }
        }
    }

    /// Requests past their deadline resolve `Err(ERR_DEADLINE)` at batch cut
    /// without executing; unexpired traffic is untouched.
    #[test]
    fn expired_requests_settle_without_executing() {
        let server = Server::start(
            stub_cfg(4, 4),
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        let dead = Completion::new();
        let live = Completion::new();
        server.submit(mk_req(1, 6).with_deadline_in(Duration::ZERO).with_completion(&dead));
        let unexpired = mk_req(2, 6).with_deadline_in(Duration::from_secs(30));
        server.submit(unexpired.with_completion(&live));
        assert!(server.await_finished(2, Duration::from_secs(5)));
        let m = server.shutdown();
        assert_eq!(m.requests_failed_deadline, 1);
        assert_eq!(m.requests_completed, 1);
        assert_eq!(m.requests_failed(), 1);
        // The expired request stays out of the latency stats it never earned.
        assert_eq!(m.latency.count(), 1);
        assert_eq!(dead.poll().expect("settled").unwrap_err(), ERR_DEADLINE);
        assert!(live.poll().expect("settled").is_ok());
    }

    /// With a bounded queue, new prefills shed once the backlog reaches the
    /// bound (the server turns Degraded), decode steps of live sessions are
    /// always admitted, and the flag clears once the queue drains.
    #[test]
    fn admission_control_sheds_prefills_and_recovers() {
        let mut cfg = stub_cfg(8, 4);
        // Nothing executes: every admitted request sits in the queue.
        cfg.policy.max_wait = Duration::from_secs(30);
        cfg.resilience = Resilience { queue_bound: 2, ..Resilience::default() };
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        assert!(server.submit(mk_req(1, 6)), "first prefill admitted");
        assert!(server.submit(mk_req(2, 6)), "second prefill admitted");
        let shed = Completion::new();
        assert!(
            !server.submit(mk_req(3, 6).with_completion(&shed)),
            "queue at bound: prefill shed"
        );
        assert_eq!(shed.poll().expect("shed resolves immediately").unwrap_err(), ERR_SHED);
        // An in-flight decode stream is protected from shedding.
        assert!(server.submit(mk_req(4, 6).with_session(9, Phase::Decode)));
        let m = server.metrics();
        assert_eq!(m.requests_shed, 1);
        assert!(m.degraded);
        assert_eq!(m.health(), "degraded");
        let m = server.shutdown();
        // Shed requests are failures, but not shutdown failures.
        assert_eq!(m.requests_failed_shutdown, 3);
        assert_eq!(m.requests_failed(), 4);
    }

    /// The Degraded flag clears (with hysteresis) once the worker drains the
    /// queue below half the bound.
    #[test]
    fn degraded_state_recovers_after_drain() {
        let mut cfg = stub_cfg(8, 4);
        cfg.resilience = Resilience { queue_bound: 2, ..Resilience::default() };
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        // Submit until one sheds: with a 1 ms wait budget the queue reaches
        // the bound long before the worker cuts a batch.
        let mut admitted = 0u64;
        let mut shed = false;
        for i in 0..10_000 {
            if server.submit(mk_req(i, 6)) {
                admitted += 1;
            } else {
                shed = true;
                break;
            }
        }
        assert!(shed, "tight-loop submission must outrun the 1 ms wait budget");
        assert!(server.metrics().degraded);
        assert!(server.await_completed(admitted, Duration::from_secs(5)));
        // The worker's hysteresis check runs each loop iteration; once the
        // queue is empty the flag must drop.
        let cleared = {
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                if !server.metrics().degraded {
                    break true;
                }
                if Instant::now() >= deadline {
                    break false;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        };
        assert!(cleared, "degraded must clear after the queue drains");
        server.shutdown();
    }

    /// Retry-pending requests (waiting out their backoff) settle as
    /// shutdown failures too — nothing is lost in the retry queue.
    #[test]
    fn shutdown_settles_retry_pending_requests() {
        let mut cfg = stub_cfg(8, 4);
        cfg.resilience = Resilience {
            max_retries: 5,
            // A backoff far beyond the test body: retries never re-enter.
            retry_backoff: Duration::from_secs(30),
            ..Resilience::default()
        };
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> {
                Err("always failing".into())
            })),
        );
        let done = Completion::new();
        server.submit(mk_req(1, 6).with_completion(&done));
        server.submit(mk_req(2, 6));
        // Wait until both first attempts failed into the retry queue.
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().retries < 2 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        let m = server.shutdown();
        assert_eq!(m.retries, 2);
        assert_eq!(m.requests_failed_shutdown, 2, "retry-pending settle at shutdown");
        assert_eq!(m.requests_failed_exec, 0);
        assert!(done.poll().expect("settled").unwrap_err().contains("shut down"));
    }

    /// Memory-pressure admission control, end to end on the latch: a hard
    /// pool failure flips the server into MemoryPressure — new prefills
    /// shed with [`ERR_SHED_MEM`] on a ledger separate from the
    /// queue-bound [`ERR_SHED`] counter, decode steps stay admitted — and
    /// the latch clears with hysteresis only once pool usage drops below
    /// half the budget.
    #[test]
    fn memory_pressure_sheds_with_distinct_reason_and_recovers() {
        use crate::arith::Format;
        use crate::kernels::{KvPagePool, PAGE_TOKENS};
        let fmt = Format::int(8);
        let codes = 4 * PAGE_TOKENS;
        let page_bytes = (codes * 8usize).div_ceil(64) * 8;
        let pool = KvPagePool::new(4 * page_bytes);
        let mut cfg = stub_cfg(8, 4);
        cfg.kv_pool = Some(pool.clone());
        let server = Server::start(
            cfg,
            Box::new(FnExecutor(|_b: &Batch| -> Result<f64, String> { Ok(0.0) })),
        );
        // Healthy: prefills admitted and served.
        assert!(server.submit(mk_req(1, 6)));
        assert!(server.await_completed(1, Duration::from_secs(5)));
        // Hold the pool more than half full and report a hard failure:
        // the worker must latch MemoryPressure and keep it latched (the
        // hysteresis condition `bytes * 2 < budget` is false at 3/4 full).
        let resident: Vec<_> = (0..3).map(|_| pool.alloc(fmt, codes).unwrap()).collect();
        pool.note_hard_failure();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !server.metrics().mem_pressure {
            assert!(Instant::now() < deadline, "worker must latch memory pressure");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(server.metrics().health(), "memory_pressure");
        // Prefills shed with the memory reason; decode steps of in-flight
        // sessions are still admitted.
        let shed = Completion::new();
        assert!(!server.submit(mk_req(2, 6).with_completion(&shed)));
        assert_eq!(
            shed.poll().expect("shed resolves immediately").unwrap_err(),
            ERR_SHED_MEM
        );
        assert!(server.submit(mk_req(3, 6).with_session(9, Phase::Decode)));
        let m = server.metrics();
        assert_eq!(m.requests_shed_mem, 1, "memory shed has its own ledger");
        assert_eq!(m.requests_shed, 0, "queue-bound shed counter is untouched");
        assert!(m.kv_pages_in_use >= 3, "pool gauges are sampled into snapshots");
        // Releasing the pages drops usage below half budget: the latch
        // clears and prefills are admitted again.
        drop(resident);
        let deadline = Instant::now() + Duration::from_secs(5);
        while server.metrics().mem_pressure {
            assert!(Instant::now() < deadline, "latch must clear after pages release");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(server.submit(mk_req(4, 6)), "prefills admitted after recovery");
        let m = server.shutdown();
        assert_eq!(m.requests_shed_mem, 1);
        assert!(m.requests_failed() >= 1, "memory sheds count as failures");
    }
}
