//! Client-side token-stream driving: the poll/resubmit loop every caller of
//! decode-phase serving needs, written once.
//!
//! A token stream is inherently sequential — step `k+1` cannot be submitted
//! until step `k`'s result is back — so a driver keeps each of its streams
//! exactly **one request deep** while interleaving many streams, which is
//! precisely the traffic shape the batcher's continuous admission turns
//! into decode batches. [`StreamDriver`] owns that loop; the caller only
//! decides, per resolved step, what the next token row is (or that the
//! stream is done).

use super::batcher::{Phase, Request};
use super::completion::{Completion, RequestResult};
use super::server::Server;
use crate::workload::{IntoPolicy, PrecisionPolicy};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One live stream the driver manages.
struct Stream {
    session: u64,
    policy: Arc<PrecisionPolicy>,
    outstanding: Completion,
    /// Steps resolved so far (0 while the prefill is outstanding).
    step: usize,
    finished: bool,
}

/// Drives a pool of token-stream sessions against a [`Server`]: submits
/// every session's prefill up front, then polls each stream's
/// [`Completion`] and asks the caller for the next token row as results
/// arrive.
pub struct StreamDriver {
    model: String,
    streams: Vec<Stream>,
    next_id: u64,
}

impl StreamDriver {
    /// Open one session per `(session_id, policy, prefill_block, dims)`
    /// entry, submitting all prefills immediately (they carry completion
    /// slots the driver polls). `policy` is anything [`IntoPolicy`] — a
    /// shared [`PrecisionPolicy`] or a bare
    /// [`crate::workload::PrecisionPair`] meaning the uniform policy.
    pub fn start<P: IntoPolicy>(
        server: &Server,
        model: impl Into<String>,
        sessions: Vec<(u64, P, Vec<f32>, Vec<usize>)>,
    ) -> Self {
        let model = model.into();
        let mut next_id = 0u64;
        let streams = sessions
            .into_iter()
            .map(|(session, policy, input, dims)| {
                let policy = policy.into_policy();
                let done = Completion::new();
                let id = next_id;
                next_id += 1;
                server.submit(
                    Request::new(id, model.clone(), &policy, input, dims)
                        .with_session(session, Phase::Prefill)
                        .with_completion(&done),
                );
                Stream { session, policy, outstanding: done, step: 0, finished: false }
            })
            .collect();
        StreamDriver { model, streams, next_id }
    }

    /// Poll all streams to completion. Each time a stream's outstanding
    /// request resolves, `on_step(stream_index, resolved_step, result)`
    /// runs (`resolved_step` 0 is the prefill, `k >= 1` the k-th decode
    /// step): return `Some(token_row)` to submit the next decode step,
    /// `None` to end the stream. A stream whose request **failed** ends
    /// regardless — the session is broken — but `on_step` still sees the
    /// error (that is the per-request failure plumbing). Returns `true`
    /// when every stream ended before `deadline`.
    pub fn run(
        &mut self,
        server: &Server,
        deadline: Instant,
        mut on_step: impl FnMut(usize, usize, RequestResult) -> Option<Vec<f32>>,
    ) -> bool {
        while self.streams.iter().any(|s| !s.finished) {
            if Instant::now() >= deadline {
                return false;
            }
            let mut progressed = false;
            for i in 0..self.streams.len() {
                if self.streams[i].finished {
                    continue;
                }
                let Some(result) = self.streams[i].outstanding.poll() else { continue };
                progressed = true;
                let failed = result.is_err();
                let next = on_step(i, self.streams[i].step, result);
                let id = self.next_id;
                self.next_id += 1;
                let s = &mut self.streams[i];
                match next {
                    Some(token) if !failed => {
                        let done = Completion::new();
                        let dims = vec![1, token.len()];
                        server.submit(
                            Request::new(id, self.model.clone(), &s.policy, token, dims)
                                .with_session(s.session, Phase::Decode)
                                .with_completion(&done),
                        );
                        s.outstanding = done;
                        s.step += 1;
                    }
                    _ => {
                        s.finished = true;
                        // Close the session server-side so its KV cache
                        // frees now instead of waiting for the executor's
                        // capacity LRU (fire-and-forget; End is idempotent).
                        server.submit(
                            Request::new(id, self.model.clone(), &s.policy, Vec::new(), Vec::new())
                                .with_session(s.session, Phase::End),
                        );
                    }
                }
            }
            if !progressed {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Batch, BatchPolicy, BatchResult, Executor, ServerConfig};
    use crate::workload::ModelSpec;

    fn tiny() -> ModelSpec {
        ModelSpec {
            seq: 8,
            layers: 1,
            d_model: 32,
            d_ff: 64,
            heads: 2,
            gated_ffn: false,
            kv_heads: 2,
            name: "tiny",
        }
    }

    /// Completes everything except session 2's decode steps — a
    /// *per-request* failure, so co-batched streams are unaffected.
    struct FailSession2Decode;
    impl Executor for FailSession2Decode {
        fn execute(&mut self, batch: &Batch) -> Result<BatchResult, String> {
            let outputs = batch
                .requests
                .iter()
                .map(|r| {
                    if r.session == 2 && r.phase == Phase::Decode {
                        Err("synthetic decode failure".to_string())
                    } else {
                        Ok(vec![r.session as f32])
                    }
                })
                .collect();
            Ok(BatchResult { host_s: 0.0, outputs, faulted: false })
        }
    }

    #[test]
    fn drives_streams_to_completion_and_reports_failures() {
        let cfg = ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_streak: 4,
            },
            sim_config: crate::sim::mobile_a(),
            sim_model: tiny(),
            recorder: crate::obs::Recorder::disabled(),
            drift: None,
            resilience: crate::coordinator::Resilience::default(),
            kv_pool: None,
        };
        let server = Server::start(cfg, Box::new(FailSession2Decode));
        let pair = crate::workload::PrecisionPair::of_bits(6, 16);
        let sessions =
            vec![(1u64, pair, vec![0.0; 8], vec![8]), (2u64, pair, vec![0.0; 8], vec![8])];
        let mut driver = StreamDriver::start(&server, "tiny", sessions);
        let steps = 3usize;
        let mut seen: Vec<Vec<Result<usize, String>>> = vec![Vec::new(), Vec::new()];
        let finished = driver.run(
            &server,
            Instant::now() + Duration::from_secs(5),
            |i, step, result| {
                seen[i].push(result.map(|v| v.len()));
                if step < steps {
                    Some(vec![0.0; 4])
                } else {
                    None
                }
            },
        );
        assert!(finished, "all streams must end");
        // Stream 0 (session 1): prefill + 3 decode steps, all Ok.
        assert_eq!(seen[0].len(), steps + 1);
        assert!(seen[0].iter().all(|r| r.is_ok()));
        // Stream 1 (session 2): prefill Ok, first decode fails, and the
        // driver ends the stream even though on_step asked to continue.
        assert_eq!(seen[1].len(), 2);
        assert!(seen[1][0].is_ok());
        assert_eq!(seen[1][1].as_ref().unwrap_err(), "synthetic decode failure");
        let m = server.shutdown();
        assert_eq!(m.sessions_started, 2);
        assert_eq!(m.decode_steps, steps as u64, "only the healthy stream's steps complete");
        assert_eq!(m.requests_failed(), 1);
        assert_eq!(m.requests_failed_exec, 1, "the failure was an executor error");
    }
}
