//! Fig 12 reproduction: performance per area (1 / (latency · accelerator
//! area)) across precision pairs, FlexiBit vs TensorCore vs Bit-Fusion.
//! Paper: FlexiBit +28% vs TensorCore and +34% vs Bit-Fusion on average,
//! with TensorCore slightly ahead at some power-of-two points.

use flexibit::area::{AcceleratorArea, PeArea};
use flexibit::baselines::{Accel, BitFusionAccel, FlexiBitAccel, TensorCoreAccel};
use flexibit::pe::PeConfig;
use flexibit::report::{geomean, Table};
use flexibit::sim::{all_configs, simulate_model, AcceleratorConfig};
use flexibit::workload::{all_models, PrecisionPair};

fn accel_area_mm2(a: &dyn Accel, cfg: &AcceleratorConfig) -> f64 {
    // PE array from each accel's PE area + shared buffers/NoC model.
    let pe_total = a.pe_area_mm2() * cfg.num_pes as f64;
    let buffers_mb = (cfg.weight_buf + cfg.act_buf) as f64 / (1024.0 * 1024.0);
    // Reuse the structural accelerator model, substituting the PE array.
    let ref_pe = PeArea::of(&PeConfig::default(), 0.18);
    let shell = AcceleratorArea::of(&ref_pe, 0, buffers_mb, cfg.channel_bits);
    pe_total + shell.total() + pe_total * 0.12 // array-side routing share
}

fn main() {
    let fb = FlexiBitAccel::new();
    let tc = TensorCoreAccel::new();
    let bf = BitFusionAccel::new();

    let pairs: Vec<PrecisionPair> =
        [(16, 16), (8, 8), (6, 16), (6, 6), (5, 5), (4, 8), (4, 4)]
            .into_iter()
            .map(|(w, a)| PrecisionPair::of_bits(w, a))
            .collect();

    let mut ratio_tc = Vec::new();
    let mut ratio_bf = Vec::new();
    for cfg in all_configs() {
        let mut table = Table::new(
            &format!("Fig 12 ({}) — performance per area (norm. to TensorCore)", cfg.name),
            &["model", "[W,A]", "FlexiBit", "TensorCore", "BitFusion"],
        );
        let areas = [
            accel_area_mm2(&fb, &cfg),
            accel_area_mm2(&tc, &cfg),
            accel_area_mm2(&bf, &cfg),
        ];
        for model in all_models() {
            for &pair in &pairs {
                let perf: Vec<f64> = [&fb as &dyn Accel, &tc, &bf]
                    .iter()
                    .zip(&areas)
                    .map(|(a, &area)| {
                        1.0 / (simulate_model(*a, &cfg, &model, pair).seconds * area)
                    })
                    .collect();
                ratio_tc.push(perf[0] / perf[1]);
                ratio_bf.push(perf[0] / perf[2]);
                table.row(vec![
                    model.name.into(),
                    pair.label(),
                    format!("{:.3}", perf[0] / perf[1]),
                    "1.000".into(),
                    format!("{:.3}", perf[2] / perf[1]),
                ]);
            }
        }
        table.print();
        println!();
    }
    println!("== §5.3.2 summary (all models x scales x pairs) ==");
    println!(
        "FlexiBit perf/area vs TensorCore: +{:.0}%  (paper: +28%)",
        100.0 * (geomean(&ratio_tc) - 1.0)
    );
    println!(
        "FlexiBit perf/area vs Bit-Fusion: +{:.0}%  (paper: +34%)",
        100.0 * (geomean(&ratio_bf) - 1.0)
    );
}
