//! Table 4 reproduction: average latency, energy, and EDP of Cambricon-P,
//! BitMoD, and FlexiBit on Llama-2-7b / Llama-2-70b at Mobile-B and
//! Cloud-B scales (W6/A16 serving point).

use flexibit::baselines::{Accel, BitModAccel, CambriconPAccel, FlexiBitAccel};
use flexibit::report::{fmt_j, fmt_s, Table};
use flexibit::sim::{cloud_b, mobile_b, simulate_model};
use flexibit::workload::{llama2_70b, llama2_7b, PrecisionPair};

fn main() {
    let accels: Vec<Box<dyn Accel>> = vec![
        Box::new(CambriconPAccel::new()),
        Box::new(BitModAccel::new()),
        Box::new(FlexiBitAccel::new()),
    ];
    let pair = PrecisionPair::of_bits(6, 16);

    let mut table = Table::new(
        "Table 4 — latency / energy / EDP (W6/A16)",
        &["scale", "accel", "lat 7b", "lat 70b", "E 7b", "E 70b", "EDP 7b", "EDP 70b"],
    );
    for cfg in [mobile_b(), cloud_b()] {
        for a in &accels {
            let r7 = simulate_model(a.as_ref(), &cfg, &llama2_7b(), pair);
            let r70 = simulate_model(a.as_ref(), &cfg, &llama2_70b(), pair);
            table.row(vec![
                cfg.name.into(),
                a.name().into(),
                fmt_s(r7.seconds),
                fmt_s(r70.seconds),
                fmt_j(r7.energy_j),
                fmt_j(r70.energy_j),
                format!("{:.2}", r7.edp()),
                format!("{:.2}", r70.edp()),
            ]);
        }
    }
    table.print();

    // Headline ratios the paper calls out.
    let cfg = cloud_b();
    let fb = simulate_model(accels[2].as_ref(), &cfg, &llama2_70b(), pair);
    let cp = simulate_model(accels[0].as_ref(), &cfg, &llama2_70b(), pair);
    let bm = simulate_model(accels[1].as_ref(), &cfg, &llama2_70b(), pair);
    println!("\nLlama-2-70b @ Cloud-B ratios:");
    println!(
        "  Cambricon-P latency vs FlexiBit: {:.0}x (paper: 52x); energy {:.1}x lower (paper table: ~20x)",
        cp.seconds / fb.seconds,
        fb.energy_j / cp.energy_j
    );
    println!(
        "  BitMoD latency vs FlexiBit: {:.1}x (paper: 7.9x); energy {:.1}x lower (paper: 2.7x)",
        bm.seconds / fb.seconds,
        fb.energy_j / bm.energy_j
    );
}
