//! Fig 13 reproduction: Energy-Delay Product of the bit-serial comparators
//! (Cambricon-P, BitMoD) and FlexiBit, normalized to the TensorCore-like
//! baseline, on Llama-2-7b / Llama-2-70b at Mobile-B and Cloud-B.
//! Paper: FlexiBit 2.48x lower EDP than Cambricon-P and 2.9x lower than
//! BitMoD on Llama-2-70b @ Cloud-B.

use flexibit::baselines::{Accel, BitModAccel, CambriconPAccel, FlexiBitAccel, TensorCoreAccel};
use flexibit::report::Table;
use flexibit::sim::{cloud_b, mobile_b, simulate_model};
use flexibit::workload::{llama2_70b, llama2_7b, PrecisionPair};

fn main() {
    let fb = FlexiBitAccel::new();
    let tc = TensorCoreAccel::new();
    let cp = CambriconPAccel::new();
    let bm = BitModAccel::new();
    // The serving precision point of §5.3.3: low-precision weights x FP16
    // activations (BitMoD's W-A16 design point).
    let pair = PrecisionPair::of_bits(6, 16);

    let mut table = Table::new(
        "Fig 13 — EDP normalized to TensorCore (W6/A16)",
        &["scale", "model", "Cambricon-P", "BitMoD", "FlexiBit"],
    );
    let mut fb_vs = Vec::new();
    for cfg in [mobile_b(), cloud_b()] {
        for model in [llama2_7b(), llama2_70b()] {
            let edp_tc = simulate_model(&tc, &cfg, &model, pair).edp();
            let rows: Vec<f64> = [&cp as &dyn Accel, &bm, &fb]
                .iter()
                .map(|a| simulate_model(*a, &cfg, &model, pair).edp() / edp_tc)
                .collect();
            if cfg.name == "Cloud-B" && model.name == "Llama-2-70b" {
                fb_vs = vec![rows[0] / rows[2], rows[1] / rows[2]];
            }
            table.row(vec![
                cfg.name.into(),
                model.name.into(),
                format!("{:.3}", rows[0]),
                format!("{:.3}", rows[1]),
                format!("{:.3}", rows[2]),
            ]);
        }
    }
    table.print();
    if fb_vs.len() == 2 {
        println!("\nLlama-2-70b @ Cloud-B:");
        println!("  FlexiBit EDP advantage vs Cambricon-P: {:.2}x (paper: 2.48x)", fb_vs[0]);
        println!("  FlexiBit EDP advantage vs BitMoD:      {:.2}x (paper: 2.9x)", fb_vs[1]);
    }
}
