//! Ablation: weight-stationary vs output-stationary dataflow (paper §5.3.1's
//! discussion — "FlexiBit's performance varies for OS and WS for different
//! accelerator scales and workload models ... we report results based on the
//! best dataflow for each experiment").
//!
//! This binary quantifies that design choice: per (model, scale), the
//! latency under forced-WS, forced-OS, and per-GEMM-best scheduling, showing
//! where the flexible dataflow (enabled by the 2-D bus NoC, §4.2) pays.

use flexibit::baselines::FlexiBitAccel;
use flexibit::report::{fmt_s, Table};
use flexibit::sim::analytical::{simulate_dataflow, simulate_gemm, Dataflow};
use flexibit::sim::{all_configs, AcceleratorConfig};
use flexibit::workload::{all_models, ModelSpec, PrecisionPair};

fn forced(
    accel: &FlexiBitAccel,
    cfg: &AcceleratorConfig,
    m: &ModelSpec,
    pair: PrecisionPair,
    df: Dataflow,
) -> f64 {
    m.gemms(pair, 0)
        .iter()
        .map(|g| simulate_dataflow(accel, cfg, g, df).seconds * g.count as f64)
        .sum()
}

fn main() {
    let fb = FlexiBitAccel::new();
    let pair = PrecisionPair::of_bits(6, 16);
    let mut table = Table::new(
        "Ablation — dataflow choice (W6/A16)",
        &["config", "model", "forced WS", "forced OS", "best-per-GEMM", "gain vs worse"],
    );
    for cfg in all_configs() {
        for model in all_models() {
            let ws = forced(&fb, &cfg, &model, pair, Dataflow::WeightStationary);
            let os = forced(&fb, &cfg, &model, pair, Dataflow::OutputStationary);
            let best: f64 = model
                .gemms(pair, 0)
                .iter()
                .map(|g| simulate_gemm(&fb, &cfg, g).seconds * g.count as f64)
                .sum();
            let worse = ws.max(os);
            table.row(vec![
                cfg.name.into(),
                model.name.into(),
                fmt_s(ws),
                fmt_s(os),
                fmt_s(best),
                format!("{:.2}x", worse / best),
            ]);
        }
    }
    table.print();
    println!("\nThe flexible dataflow pays most where WS and OS diverge (memory-bound");
    println!("mobile configs / large-K GEMMs), matching the paper's §5.3.1 discussion.");
}
