//! Fig 11 reproduction: FlexiBit with and without the BitPacking unit,
//! normalized to TensorCore latency per precision (the paper reports a 26%
//! average latency improvement from BitPacking).

use flexibit::baselines::{Accel, FlexiBitAccel, TensorCoreAccel};
use flexibit::report::{geomean, Table};
use flexibit::sim::{mobile_b, simulate_model};
use flexibit::workload::{all_models, PrecisionPair};

fn main() {
    let fb = FlexiBitAccel::new();
    let fb_nobp = FlexiBitAccel::without_bit_packing();
    let tc = TensorCoreAccel::new();
    let cfg = mobile_b(); // memory-bound scale shows the packing effect best

    let pairs: Vec<PrecisionPair> = [(16, 16), (8, 8), (6, 16), (6, 6), (5, 5), (4, 4)]
        .into_iter()
        .map(|(w, a)| PrecisionPair::of_bits(w, a))
        .collect();

    let mut table = Table::new(
        &format!("Fig 11 — BitPacking ablation ({}, normalized to TensorCore)", cfg.name),
        &["model", "[W,A]", "FB+BP / TC", "FB-noBP / TC", "BP gain"],
    );
    let mut gains = Vec::new();
    for model in all_models() {
        for &pair in &pairs {
            let t_tc = simulate_model(&tc, &cfg, &model, pair).seconds;
            let t_bp = simulate_model(&fb, &cfg, &model, pair).seconds;
            let t_no = simulate_model(&fb_nobp, &cfg, &model, pair).seconds;
            gains.push(t_no / t_bp);
            table.row(vec![
                model.name.into(),
                pair.label(),
                format!("{:.3}", t_bp / t_tc),
                format!("{:.3}", t_no / t_tc),
                format!("{:.2}x", t_no / t_bp),
            ]);
        }
    }
    table.print();
    println!(
        "\naverage BitPacking latency improvement: {:.0}%  (paper: 26%)",
        100.0 * (1.0 - 1.0 / geomean(&gains))
    );
}
