//! Fig 9 reproduction: performance-model validation.
//!
//! The paper validates its cycle-accurate simulator against RTL simulation
//! on the attention layers of Bert-base and Llama-2-7b (96% / 99%
//! agreement). Our analog validates the fast analytical model (used for the
//! campaign) against the detailed cycle-level simulator on the same
//! workloads, reporting per-layer latencies and aggregate agreement.

use flexibit::baselines::FlexiBitAccel;
use flexibit::report::{fmt_s, Table};
use flexibit::sim::cycle::simulate_gemm_cycles;
use flexibit::sim::{analytical::simulate_gemm, mobile_a};
use flexibit::workload::{bert_base, llama2_7b, PrecisionPair};

fn main() {
    let fb = FlexiBitAccel::new();
    let cfg = mobile_a();
    let pair = PrecisionPair::of_bits(6, 16);

    let mut table = Table::new(
        "Fig 9 — performance model validation (attention layers, Mobile-A, W6/A16)",
        &["model", "gemm", "cycle-level", "analytical", "agreement"],
    );
    for model in [bert_base(), llama2_7b()] {
        let mut cyc_total = 0.0;
        let mut ana_total = 0.0;
        for g in model.attention_gemms(pair) {
            let cyc = simulate_gemm_cycles(&fb, &cfg, &g).seconds * g.count as f64;
            let ana = simulate_gemm(&fb, &cfg, &g).seconds * g.count as f64;
            cyc_total += cyc;
            ana_total += ana;
            let agree = 100.0 * (1.0 - (cyc - ana).abs() / cyc.max(ana));
            table.row(vec![
                model.name.into(),
                format!("{:?}", g.kind),
                fmt_s(cyc),
                fmt_s(ana),
                format!("{agree:.1}%"),
            ]);
        }
        let agree = 100.0 * (1.0 - (cyc_total - ana_total).abs() / cyc_total.max(ana_total));
        table.row(vec![
            model.name.into(),
            "TOTAL".into(),
            fmt_s(cyc_total),
            fmt_s(ana_total),
            format!("{agree:.1}%"),
        ]);
    }
    table.print();
    println!("\npaper: simulator-vs-RTL agreement 96% (Bert-base), 99% (Llama-2-7b)");
}
