//! Fig 10 reproduction: end-to-end latency of the four models on the
//! precision-pair axis, FlexiBit vs TensorCore vs Bit-Fusion, for each of
//! the four accelerator scales (sub-figures a-d), plus the §5.3.1 averages
//! (FP6: 59% less latency than TensorCore, 31% less than Bit-Fusion).

use flexibit::baselines::{Accel, BitFusionAccel, FlexiBitAccel, TensorCoreAccel};
use flexibit::report::{fmt_s, geomean, Table};
use flexibit::sim::{all_configs, simulate_model};
use flexibit::workload::{all_models, PrecisionPair};

/// The precision-pair axis of Fig 10: `[P(W), P(A)]`.
pub fn precision_axis() -> Vec<PrecisionPair> {
    [(16, 16), (8, 16), (8, 8), (6, 16), (6, 6), (5, 5), (4, 16), (4, 8), (4, 4)]
        .into_iter()
        .map(|(w, a)| PrecisionPair::of_bits(w, a))
        .collect()
}

fn main() {
    let fb = FlexiBitAccel::new();
    let tc = TensorCoreAccel::new();
    let bf = BitFusionAccel::new();
    let accels: Vec<&dyn Accel> = vec![&fb, &tc, &bf];

    let mut fp6_ratios_tc = Vec::new();
    let mut fp6_ratios_bf = Vec::new();

    for cfg in all_configs() {
        let mut table = Table::new(
            &format!("Fig 10 ({}) — latency, seq 2048", cfg.name),
            &["model", "[W,A]", "FlexiBit", "TensorCore", "BitFusion", "FB vs TC", "FB vs BF"],
        );
        for model in all_models() {
            for pair in precision_axis() {
                let t: Vec<f64> = accels
                    .iter()
                    .map(|a| simulate_model(*a, &cfg, &model, pair).seconds)
                    .collect();
                if pair.w.bits() == 6 {
                    fp6_ratios_tc.push(t[1] / t[0]);
                    fp6_ratios_bf.push(t[2] / t[0]);
                }
                table.row(vec![
                    model.name.into(),
                    pair.label(),
                    fmt_s(t[0]),
                    fmt_s(t[1]),
                    fmt_s(t[2]),
                    format!("{:.2}x", t[1] / t[0]),
                    format!("{:.2}x", t[2] / t[0]),
                ]);
            }
        }
        table.print();
        println!();
    }

    let g_tc = geomean(&fp6_ratios_tc);
    let g_bf = geomean(&fp6_ratios_bf);
    println!("== §5.3.1 summary (FP6-weight rows, all models x scales) ==");
    println!(
        "FlexiBit latency reduction vs TensorCore: {:.0}%  (paper: 59%)",
        100.0 * (1.0 - 1.0 / g_tc)
    );
    println!(
        "FlexiBit latency reduction vs Bit-Fusion: {:.0}%  (paper: 31%)",
        100.0 * (1.0 - 1.0 / g_bf)
    );
}
