//! Table 5 reproduction: area and power at the Mobile-A scale for
//! Cambricon-P, BitMoD, and FlexiBit, from the structural area model plus
//! the energy model's busy-power on a representative run.

use flexibit::area::{AcceleratorArea, PeArea};
use flexibit::baselines::{Accel, BitModAccel, CambriconPAccel, FlexiBitAccel};
use flexibit::pe::PeConfig;
use flexibit::report::Table;
use flexibit::sim::{mobile_a, simulate_model};
use flexibit::workload::{bert_base, PrecisionPair};

fn main() {
    let cfg = mobile_a();
    let pair = PrecisionPair::of_bits(6, 16);
    let buffers_mb = (cfg.weight_buf + cfg.act_buf) as f64 / (1024.0 * 1024.0);

    let fb = FlexiBitAccel::new();
    let cp = CambriconPAccel::new();
    let bm = BitModAccel::new();

    let mut table = Table::new(
        "Table 5 — area and power @ Mobile-A",
        &["accel", "area mm^2 (ours)", "area (paper)", "power mW (ours)", "power (paper)"],
    );
    let paper = [("Cambricon-P", 5.11, 122.15), ("BitMoD", 4.70, 629.76), ("FlexiBit", 18.62, 873.48)];
    for (a, (pname, parea, ppow)) in
        [&cp as &dyn Accel, &bm, &fb].iter().zip(paper.iter())
    {
        assert_eq!(a.name(), *pname);
        // Area: PE array at each architecture's PE size + shared shell.
        let area = if a.name() == "FlexiBit" {
            let pe = PeArea::of(&PeConfig::default(), 0.18);
            AcceleratorArea::of(&pe, cfg.num_pes, buffers_mb, cfg.channel_bits).total()
        } else {
            // Bit-serial accelerators: small PEs + their own (smaller)
            // buffer provisioning per their papers (~1 MB class).
            a.pe_area_mm2() * cfg.num_pes as f64 * 1.12 + 1.0 * 1024.0 * 1950.0 * 1e-6
        };
        // Power: busy power over a representative workload run.
        let rep = simulate_model(*a, &cfg, &bert_base(), pair);
        let power_w = rep.counts.avg_power_w(&a.energy_table(cfg.mobile));
        table.row(vec![
            a.name().into(),
            format!("{area:.2}"),
            format!("{parea:.2}"),
            format!("{:.0}", power_w * 1000.0),
            format!("{ppow:.0}"),
        ]);
    }
    table.print();
    println!("\n(paper values from post-PnR synthesis; ours from the structural area model");
    println!(" and the Accelergy-style busy-power estimate on Bert-base W6/A16)");
}
