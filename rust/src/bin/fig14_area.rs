//! Fig 14 reproduction: (a) PE area breakdown + throughput-per-area across
//! reg_width 16..32 (the sweep that selected reg_width = 24), and (b) the
//! accelerator-level area breakdown at Mobile-A.

use flexibit::area::{AcceleratorArea, PeArea};
use flexibit::pe::PeConfig;
use flexibit::report::{geomean, Table};
use flexibit::workload::PrecisionPair;

fn main() {
    // ---- (a) reg_width sweep -------------------------------------------
    let mut sweep = Table::new(
        "Fig 14 (a) — PE area and throughput/area vs reg_width",
        &["reg_width", "PE area (um^2)", "flex-core %", "avg mults/cyc", "tput/area (norm)"],
    );
    // The headline precision mix of the evaluation (Fig 10's pow-2 points,
    // the FP6 pair, and the W6/A16 serving point).
    let pairs: Vec<PrecisionPair> = [(16, 16), (8, 8), (6, 16), (6, 6), (4, 4)]
        .into_iter()
        .map(|(w, a)| PrecisionPair::of_bits(w, a))
        .collect();
    let mut best = (0usize, 0.0f64);
    let mut norm = None;
    for rw in [16usize, 20, 24, 28, 32] {
        let cfg = PeConfig::with_reg_width(rw);
        let pe = PeArea::of(&cfg, 0.18);
        let tput = geomean(
            &pairs
                .iter()
                .map(|p| cfg.mults_per_cycle(p.a, p.w) as f64)
                .collect::<Vec<_>>(),
        );
        let tpa = tput / pe.total();
        let n = *norm.get_or_insert(tpa);
        if tpa > best.1 {
            best = (rw, tpa);
        }
        sweep.row(vec![
            rw.to_string(),
            format!("{:.0}", pe.total() * 1e6),
            format!("{:.0}%", pe.flex_core_fraction() * 100.0),
            format!("{tput:.2}"),
            format!("{:.3}", tpa / n),
        ]);
    }
    sweep.print();
    println!("best throughput/area at reg_width = {} (paper: 24)\n", best.0);

    // ---- (a) PE breakdown at the default -------------------------------
    let pe = PeArea::of(&PeConfig::default(), 0.18);
    let mut bd = Table::new(
        "Fig 14 (a) — PE area breakdown (reg_width = 24)",
        &["component", "um^2", "share"],
    );
    let parts: Vec<(&str, f64)> = vec![
        ("Separator crossbars", pe.separator_xbar),
        ("Primitive Generator", pe.primgen_xbar),
        ("FBRT", pe.fbrt),
        ("FBEA", pe.fbea),
        ("CST", pe.cst),
        ("ANU", pe.anu),
        ("Registers", pe.registers),
        ("Local buffer", pe.local_buffer),
        ("Routing/wiring", pe.routing),
    ];
    for (name, a) in &parts {
        bd.row(vec![
            (*name).into(),
            format!("{:.0}", a * 1e6),
            format!("{:.1}%", a / pe.total() * 100.0),
        ]);
    }
    bd.print();
    println!(
        "FBRT + Primitive Generator share: {:.0}% (paper: ~50%)\n",
        pe.flex_core_fraction() * 100.0
    );

    // ---- (b) accelerator breakdown at Mobile-A --------------------------
    let acc = AcceleratorArea::of(&pe, 1024, 3.0, 64);
    let mut ab = Table::new(
        "Fig 14 (b) — accelerator area breakdown (Mobile-A, reg_width = 24)",
        &["component", "mm^2", "share"],
    );
    for (name, a) in [
        ("PE array", acc.pe_array),
        ("Global buffers", acc.global_buffers),
        ("NoC / routing", acc.noc_routing),
        ("Bit-Packing Unit", acc.bpu),
        ("Controller + CSRs", acc.controller),
    ] {
        ab.row(vec![
            name.into(),
            format!("{a:.3}"),
            format!("{:.2}%", a / acc.total() * 100.0),
        ]);
    }
    ab.print();
    println!(
        "total: {:.2} mm^2 (paper Table 5: 18.62 mm^2); routing share {:.0}% (paper: 12%); BPU {:.2}% (negligible)",
        acc.total(),
        acc.noc_routing / acc.total() * 100.0,
        acc.bpu / acc.total() * 100.0
    );
}
