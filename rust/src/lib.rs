//! # FlexiBit — fully flexible precision bit-parallel accelerator (reproduction)
//!
//! This crate reproduces the system from *"FlexiBit: Fully Flexible Precision
//! Bit-parallel Accelerator Architecture for Arbitrary Mixed Precision AI"*
//! (Tahmasebi et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the accelerator itself: a bit-exact functional model
//!   of the FlexiBit processing element ([`pe`]), a cycle-level + analytical
//!   performance simulator ([`sim`]), the four baseline accelerators
//!   ([`baselines`]), energy/area models ([`energy`], [`area`]), the LLM
//!   workload extraction ([`workload`]), the static control-signal compiler
//!   ([`compiler`]), the bit-packing unit ([`bitpack`]), a native bit-packed
//!   GEMM execution engine ([`kernels`]) that serves any precision pair in
//!   pure Rust, a serving coordinator ([`coordinator`]) that co-runs an
//!   execution backend ([`kernels`] by default, PJRT via [`runtime`] with
//!   `--features pjrt`) with the simulator, an observability layer
//!   ([`obs`]) — request/kernel span tracing, hot-path counters, latency
//!   histograms, chrome-trace/Prometheus exporters, and a sim-vs-measured
//!   drift auditor — and a deterministic closed/open-loop traffic harness
//!   ([`loadgen`]) that proves the serving numbers under shaped load.
//! * **L2/L1 (python/)** — a JAX transformer block whose GEMMs run through a
//!   Pallas arbitrary-ExMy dequantize-GEMM kernel, AOT-lowered to HLO text
//!   artifacts loaded by [`runtime`] (optional; the native engine needs no
//!   artifacts).
//!
//! See `DESIGN.md` for the paper-to-module inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod util;
pub mod arith;
pub mod pe;
pub mod bitpack;
pub mod compiler;
pub mod workload;
pub mod sim;
pub mod baselines;
pub mod energy;
pub mod area;
pub mod kernels;
pub mod obs;
pub mod coordinator;
pub mod loadgen;
pub mod runtime;
pub mod report;
