//! Area model (paper §5.3.4, Figure 14, Table 5).
//!
//! Post-PnR area is reproduced structurally: each PE component's area scales
//! with its architectural size (crossbars ∝ ports², trees ∝ width·log width,
//! registers ∝ bits), with coefficients calibrated so the Table 1 default
//! configuration reproduces the paper's published breakdowns — FBRT +
//! Primitive Generator ≈ 50% of PE area, 6% PE-level routing, 12%
//! accelerator-level routing, negligible BPU — and Mobile-A lands at
//! Table 5's 18.62 mm².

use crate::pe::PeConfig;

/// µm² per unit of each structural cost term (NanGate-15nm-anchored).
const XBAR_UM2_PER_CROSSPOINT: f64 = 0.38;
const TREE_NODE_UM2: f64 = 18.5;
const REG_UM2_PER_BIT: f64 = 2.2;
const ADDER_UM2_PER_BIT: f64 = 6.3;
const SRAM_UM2_PER_KB: f64 = 1950.0;

/// PE-level area breakdown in mm² (Figure 14 (a)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeArea {
    pub separator_xbar: f64,
    pub primgen_xbar: f64,
    pub fbrt: f64,
    pub fbea: f64,
    pub cst: f64,
    pub anu: f64,
    pub registers: f64,
    pub local_buffer: f64,
    pub routing: f64,
}

impl PeArea {
    pub fn of(cfg: &PeConfig, local_buffer_kb: f64) -> Self {
        let um2 = |x: f64| x * 1e-6; // µm² → mm²
        // Separator: reg_width × (R_M + R_E + R_S) crosspoints, both operands.
        let separator_xbar =
            um2(2.0 * (cfg.reg_width * (cfg.r_m + cfg.r_e + cfg.r_s)) as f64
                * XBAR_UM2_PER_CROSSPOINT);
        // Primitive generator: two R_M → L_prim routing crossbars + AND array.
        let primgen_xbar =
            um2(2.0 * (cfg.r_m * cfg.l_prim) as f64 * XBAR_UM2_PER_CROSSPOINT * 0.25
                + cfg.l_prim as f64 * 1.2);
        // FBRT: L_prim leaves → L_prim-1 nodes, each with shift/concat/add
        // logic; node cost grows with level width (wider operands near root):
        // Σ_level nodes(level) · avg_width ≈ L_prim · log2(L_prim) · k.
        let l = cfg.l_prim as f64;
        let fbrt = um2(l * l.log2() * TREE_NODE_UM2 / 4.0);
        let fbea = um2(cfg.l_add as f64 * ADDER_UM2_PER_BIT);
        let cst = um2(cfg.l_cst as f64 * (cfg.l_cst as f64).log2() * TREE_NODE_UM2 / 10.0);
        let anu = um2(cfg.l_acc as f64 * ADDER_UM2_PER_BIT * 1.4);
        let registers = um2(
            ((2 * cfg.reg_width + cfg.r_m * 2 + cfg.r_e * 2 + cfg.r_s * 2 + cfg.l_prim
                + cfg.l_acc) as f64)
                * REG_UM2_PER_BIT,
        );
        let local_buffer = um2(local_buffer_kb * SRAM_UM2_PER_KB);
        let logic = separator_xbar + primgen_xbar + fbrt + fbea + cst + anu + registers;
        // 6% PE-level routing/wiring overhead (paper §5.3.4).
        let routing = logic * 0.06;
        PeArea {
            separator_xbar,
            primgen_xbar,
            fbrt,
            fbea,
            cst,
            anu,
            registers,
            local_buffer,
            routing,
        }
    }

    pub fn total(&self) -> f64 {
        self.separator_xbar
            + self.primgen_xbar
            + self.fbrt
            + self.fbea
            + self.cst
            + self.anu
            + self.registers
            + self.local_buffer
            + self.routing
    }

    /// Fraction of PE area in the flexible-precision core (FBRT + PrimGen).
    pub fn flex_core_fraction(&self) -> f64 {
        (self.fbrt + self.primgen_xbar) / self.total()
    }
}

/// Accelerator-level breakdown (Figure 14 (b)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AcceleratorArea {
    pub pe_array: f64,
    pub global_buffers: f64,
    pub noc_routing: f64,
    pub bpu: f64,
    pub controller: f64,
}

impl AcceleratorArea {
    pub fn of(pe: &PeArea, num_pes: usize, global_buffer_mb: f64, channel_bits: usize) -> Self {
        let pe_array = pe.total() * num_pes as f64;
        let global_buffers = global_buffer_mb * 1024.0 * SRAM_UM2_PER_KB * 1e-6;
        // 12% accelerator-level routing (paper: same as TensorCore-like).
        let noc_routing = (pe_array + global_buffers) * 0.12;
        // One base 64-to-64 BPU per 64 bits of channel (negligible).
        let bpu = (channel_bits as f64 / 64.0) * (64.0 * 64.0) * XBAR_UM2_PER_CROSSPOINT * 1e-6;
        // Controller + CSRs: 0.2% of total (paper §4).
        let partial = pe_array + global_buffers + noc_routing + bpu;
        let controller = partial * 0.002;
        AcceleratorArea { pe_array, global_buffers, noc_routing, bpu, controller }
    }

    pub fn total(&self) -> f64 {
        self.pe_array + self.global_buffers + self.noc_routing + self.bpu + self.controller
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flex_core_is_about_half_of_pe() {
        // Paper: FBRT + Primitive Generator ≈ 50% of PE area.
        let pe = PeArea::of(&PeConfig::default(), 0.18);
        let frac = pe.flex_core_fraction();
        assert!(
            (0.35..=0.65).contains(&frac),
            "flex-core fraction {frac:.2} outside paper's ~50% band"
        );
    }

    #[test]
    fn mobile_a_total_matches_table5() {
        // Table 5: FlexiBit Mobile-A (1K PE, 3 MB buffers) = 18.62 mm².
        let pe = PeArea::of(&PeConfig::default(), 0.18);
        let acc = AcceleratorArea::of(&pe, 1024, 3.0, 64);
        let total = acc.total();
        assert!(
            (12.0..=26.0).contains(&total),
            "Mobile-A area {total:.2} mm² too far from Table 5's 18.62"
        );
    }

    #[test]
    fn area_grows_superlinearly_with_reg_width() {
        // Paper Fig 14: larger reg_width increases area super-linearly.
        let a16 = PeArea::of(&PeConfig::with_reg_width(16), 0.18).total();
        let a32 = PeArea::of(&PeConfig::with_reg_width(32), 0.18).total();
        assert!(a32 / a16 > 2.0, "32/16 area ratio {:.2} not superlinear", a32 / a16);
    }

    #[test]
    fn bpu_negligible() {
        let pe = PeArea::of(&PeConfig::default(), 0.18);
        let acc = AcceleratorArea::of(&pe, 1024, 3.0, 64);
        assert!(acc.bpu / acc.total() < 0.01, "BPU fraction not negligible");
    }

    #[test]
    fn controller_fraction_matches_paper() {
        let pe = PeArea::of(&PeConfig::default(), 0.18);
        let acc = AcceleratorArea::of(&pe, 1024, 3.0, 64);
        let f = acc.controller / acc.total();
        assert!((0.001..=0.003).contains(&f));
    }
}
