//! Chaos end-to-end tests: seeded fault injection against the real native
//! engine. The claims under test are the PR's headline guarantees —
//!
//! * every request resolves exactly once, with its final (post-retry)
//!   result, no matter what the executor does underneath;
//! * a retried decode stream is bit-identical to a fault-free run: the
//!   KV rollback + token ledger make a retry indistinguishable from a
//!   first attempt (asserted via the order-independent `output_digest`);
//! * the drift auditor's ledger stays balanced under faults
//!   (`audited + skipped == batches_executed`);
//! * two identical seeded chaos runs fault — and heal — identically;
//! * under a KV page budget (with or without armed `oom:` allocation
//!   faults), preempted sessions re-prefill and finish bit-identically to
//!   an unconstrained run — memory pressure degrades capacity, never
//!   correctness.

use flexibit::coordinator::{BatchPolicy, Executor, Resilience, Server, ServerConfig};
use flexibit::kernels::{KvPagePool, NativeExecutor, PAGE_TOKENS};
use flexibit::loadgen::{run, Arrival, Dist, FaultPlan, FaultyExecutor, LoadReport, Scenario};
use flexibit::obs::Recorder;
use flexibit::workload::{IntoPolicy, ModelSpec, PrecisionPair};
use std::time::Duration;

/// The CI scenario shape: mixed prefill/decode over two precision pairs.
fn scenario(seed: u64) -> Scenario {
    Scenario {
        seed,
        sessions: 6,
        arrival: Arrival::Closed { concurrency: 3, think_s: 0.0 },
        prefill_len: Dist::Uniform(2, 6),
        decode_steps: Dist::Fixed(3),
        policies: vec![
            PrecisionPair::of_bits(6, 6).into_policy(),
            PrecisionPair::of_bits(8, 8).into_policy(),
        ],
        shared_prefix: 0,
    }
}

/// One seeded run against the native engine, optionally wrapped in a
/// seeded [`FaultyExecutor`] and optionally under a KV page budget
/// (`kv_budget` bytes). Retries are generous (the faults are the test
/// subject, not the retry budget) and the backoff is short so the
/// exponential schedule never dominates the run.
fn chaos_run(seed: u64, faults: Option<&str>, kv_budget: Option<usize>) -> LoadReport {
    let spec = ModelSpec::tiny();
    let pool = kv_budget.map(KvPagePool::new);
    let mut native = NativeExecutor::new().with_model(spec.clone(), 0xF1E81B);
    if let Some(p) = &pool {
        native = native.with_kv_pool(p.clone());
    }
    let executor: Box<dyn Executor> = match faults {
        Some(s) => {
            let plan = FaultPlan::parse(s, seed).expect("test fault spec parses");
            let mut faulty = FaultyExecutor::new(Box::new(native), plan);
            if let Some(p) = &pool {
                faulty = faulty.with_kv_pool(p.clone());
            }
            Box::new(faulty)
        }
        None => Box::new(native),
    };
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_streak: 4,
            },
            sim_config: flexibit::sim::mobile_a(),
            sim_model: spec.clone(),
            recorder: Recorder::disabled(),
            drift: None,
            resilience: Resilience {
                max_retries: 16,
                retry_backoff: Duration::from_micros(100),
                ..Default::default()
            },
            kv_pool: pool,
        },
        executor,
    );
    let mut report = run(&server, &spec, &scenario(seed), Duration::from_secs(120));
    report.metrics = server.shutdown();
    assert!(!report.timed_out, "chaos run must drain within the timeout");
    report
}

/// The healing invariants every chaos run must satisfy, faults or not.
fn assert_healed(chaos: &LoadReport, clean: &LoadReport, tag: &str) {
    assert_eq!(chaos.counts.submitted, clean.counts.submitted, "{tag}: same schedule");
    assert_eq!(chaos.counts.failed, 0, "{tag}: retries absorb every injected fault");
    assert_eq!(chaos.counts.completed, clean.counts.completed, "{tag}: exactly-once");
    assert_eq!(chaos.counts.decode_tokens, clean.counts.decode_tokens, "{tag}");
    // The headline claim: rolled-back, re-executed streams produce the
    // same bits a fault-free run does.
    assert_eq!(
        chaos.counts.output_digest, clean.counts.output_digest,
        "{tag}: retried streams must be bit-identical to fault-free"
    );
    let m = &chaos.metrics;
    assert_eq!(m.requests_failed(), 0, "{tag}: no request settles failed");
    assert_eq!(
        m.drift.audited() + m.drift.skipped(),
        m.batches_executed,
        "{tag}: drift ledger balanced under faults"
    );
}

#[test]
fn transient_faults_heal_bit_identically_and_deterministically() {
    let clean = chaos_run(7, None, None);
    assert_eq!(clean.counts.failed, 0);
    assert_eq!(clean.counts.completed, 6 * 4, "1 prefill + Fixed(3) decodes per session");
    assert_eq!(clean.metrics.retries, 0, "no faults, no retries");

    // Transient errors + latency spikes: per-request faults whose retry
    // chains are a pure function of (seed, id, attempt) — so counts, not
    // just outputs, must reproduce run to run.
    let spec = "error:0.3,delay:0.1:0.0005";
    let chaos = chaos_run(7, Some(spec), None);
    assert_healed(&chaos, &clean, "error+delay");
    let m = &chaos.metrics;
    assert!(m.retries > 0, "error faults at rate 0.3 must have fired");
    assert!(m.retry_success > 0, "some request must have healed on a re-attempt");
    assert!(m.drift.skipped() > 0, "faulted batches route to the skip ledger, not the audit");
    assert_eq!(m.batches_panicked, 0, "no panic fates in this plan");

    // Bit-reproducible chaos: an identical seeded run faults and heals
    // identically, down to the retry counts.
    let again = chaos_run(7, Some(spec), None);
    assert_healed(&again, &clean, "error+delay rerun");
    assert_eq!(again.counts.output_digest, chaos.counts.output_digest);
    assert_eq!(again.metrics.retries, m.retries, "same seed, same retry chains");
    assert_eq!(again.metrics.retry_success, m.retry_success);
}

#[test]
fn panic_faults_poison_batches_but_every_stream_heals() {
    // Panics poison whole batches (collateral co-batched requests retry
    // too), so which *batch* dies depends on composition — but the healing
    // invariants must hold per run, and across a few seeds at these rates
    // at least one batch is certain to have been poisoned.
    let mut batches_panicked = 0;
    for seed in [7, 11, 13] {
        let clean = chaos_run(seed, None, None);
        let chaos = chaos_run(seed, Some("panic:0.12,error:0.08"), None);
        batches_panicked += chaos.metrics.batches_panicked;
        assert_healed(&chaos, &clean, &format!("panic seed {seed}"));
    }
    assert!(batches_panicked >= 1, "panic fates must have poisoned at least one batch");
}

#[test]
fn kv_budget_preemption_and_oom_faults_heal_bit_identically() {
    let clean = chaos_run(7, None, None);
    assert_eq!(clean.counts.failed, 0);

    // A budget of exactly two sessions' worth of pages: every stream in the
    // scenario fits in one page per (layer, kv head, K/V) at 8 bits, so with
    // three concurrent sessions the executor *must* preempt to make the third
    // fit — but a lone session can always re-prefill, so no allocation ever
    // hard-fails and nothing is shed.
    let spec = ModelSpec::tiny();
    let page_bytes = (spec.head_dim() * PAGE_TOKENS * 8).div_ceil(64) * 8;
    let budget = spec.layers * spec.kv_heads * 2 * page_bytes * 2;

    let tight = chaos_run(7, None, Some(budget));
    assert_healed(&tight, &clean, "kv budget");
    assert!(
        tight.metrics.sessions_preempted > 0,
        "a 2-session budget under 3-way concurrency must preempt"
    );
    assert_eq!(tight.metrics.requests_shed_mem, 0, "preemption absorbs pressure without shedding");

    // Armed `oom:` faults on top of the budget: the next page allocation
    // after an armed batch hard-fails, the executor heals by preempt +
    // re-prefill, and the outputs still match the unconstrained run. Which
    // victim gets preempted depends on batch composition (timing), so we
    // assert the bit-exact invariants and that preemption fired — not an
    // exact preemption count across runs.
    let a = chaos_run(7, Some("oom:0.2"), Some(budget));
    let b = chaos_run(7, Some("oom:0.2"), Some(budget));
    assert_healed(&a, &clean, "oom faults");
    assert_healed(&b, &clean, "oom faults rerun");
    assert_eq!(a.counts.output_digest, b.counts.output_digest, "seeded oom runs match bits");
    assert!(a.metrics.sessions_preempted > 0, "armed oom faults must force preemption");
    assert!(b.metrics.sessions_preempted > 0, "armed oom faults must force preemption");
}
