//! End-to-end tests of the per-layer mixed-precision policy engine:
//!
//! * a genuinely mixed policy (E4M3 attention / FP6 FFN / INT8 down on
//!   layer 0, a different assignment on layer 1) run through
//!   `forward_prefill` + `forward_decode` is **bit-exact** against an
//!   oracle composed from `gemm_ref` per-layer at each projection's own
//!   formats — for both an MHA/GELU and a GQA/SwiGLU model;
//! * the offline policy search is deterministic (stable digest, identical
//!   JSON) and its output round-trips through `parse_json`;
//! * one checkpoint serves two *named* policies in a single loadgen run:
//!   zero KV repacks, nonzero zero-copy adoptions, a balanced drift
//!   ledger, and one co-simulated cost entry per distinct policy digest.
//!
//! The oracle cannot borrow the model's weight matrices (they are
//! crate-private), so it replays `NativeModel::synthesize`'s seeded draw
//! order — same `Rng`, same init order, same 1/sqrt(fan_in) scaling —
//! which is itself asserted by the bitwise comparison: a drift in either
//! copy breaks every assert below.

use flexibit::arith::{encode, gemm_ref, Format};
use flexibit::coordinator::{BatchPolicy, Resilience, Server, ServerConfig};
use flexibit::kernels::{
    search_policy, KvCache, NativeExecutor, NativeModel, SearchConfig, WeightCache,
};
use flexibit::loadgen::{run, Arrival, Dist, Scenario};
use flexibit::obs::{Counter, Recorder};
use flexibit::util::Rng;
use flexibit::workload::{IntoPolicy, LayerPolicy, ModelSpec, PrecisionPair, PrecisionPolicy};
use std::sync::Arc;
use std::time::Duration;

fn fmt(s: &str) -> Format {
    Format::parse(s).unwrap_or_else(|| panic!("test format {s} parses"))
}

fn pair(w: &str, a: &str) -> PrecisionPair {
    PrecisionPair::new(fmt(w), fmt(a))
}

/// The ISSUE's example policy: E4M3 attention, FP6 gate/up, INT8 down on
/// layer 0 — and a deliberately different layer 1 so per-layer routing
/// (not just per-projection) is exercised. Activation is uniform E4M3.
fn mixed_policy() -> PrecisionPolicy {
    let l0 = LayerPolicy {
        qkv: pair("e4m3", "e4m3"),
        out: pair("e4m3", "e4m3"),
        gate_up: pair("e3m2", "e4m3"),
        down: pair("int8", "e4m3"),
    };
    let l1 = LayerPolicy {
        qkv: pair("e3m2", "e4m3"),
        out: pair("e2m2", "e4m3"),
        gate_up: pair("e4m3", "e4m3"),
        down: pair("e3m2", "e4m3"),
    };
    PrecisionPolicy::new("mixed-e2e", vec![l0, l1])
}

/// Oracle copy of one layer's f32 master weights.
struct RefLayer {
    wqkv: Vec<f32>,
    wo: Vec<f32>,
    w_up: Vec<f32>,
    w_gate: Option<Vec<f32>>,
    w_down: Vec<f32>,
}

/// Replays `NativeModel::synthesize(spec, seed)`'s exact draw order.
fn synth_ref(spec: &ModelSpec, seed: u64) -> Vec<RefLayer> {
    let mut rng = Rng::new(seed);
    let d = spec.d_model;
    let kv_dim = spec.kv_heads * spec.head_dim();
    let mut init = |rows: usize, cols: usize| -> Vec<f32> {
        let scale = 1.0 / (rows as f64).sqrt();
        (0..rows * cols).map(|_| (rng.gauss() * scale) as f32).collect()
    };
    (0..spec.layers)
        .map(|_| RefLayer {
            wqkv: init(d, d + 2 * kv_dim),
            wo: init(d, d),
            w_up: init(d, spec.d_ff),
            w_gate: if spec.gated_ffn { Some(init(d, spec.d_ff)) } else { None },
            w_down: init(spec.d_ff, d),
        })
        .collect()
}

/// Quantize-then-`gemm_ref`: the reference for what one packed GEMM at
/// (`a_fmt` x `w_fmt`) must produce, bit for bit.
fn ref_gemm(
    a: &[f32],
    a_fmt: Format,
    w: &[f32],
    w_fmt: Format,
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let ac: Vec<u32> = a.iter().map(|&v| encode(v as f64, a_fmt)).collect();
    let wc: Vec<u32> = w.iter().map(|&v| encode(v as f64, w_fmt)).collect();
    gemm_ref(&ac, a_fmt, &wc, w_fmt, m, k, n)
}

fn add_in_place(x: &mut [f32], y: &[f32]) {
    for (a, b) in x.iter_mut().zip(y) {
        *a += b;
    }
}

fn rms_norm(x: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    for (row, orow) in x.chunks(d).zip(out.chunks_mut(d)) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for (o, &v) in orow.iter_mut().zip(row) {
            *o = v * inv;
        }
    }
    out
}

fn softmax_rows(scores: &mut [f32], n: usize) {
    for row in scores.chunks_mut(n) {
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        if sum > 0.0 {
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Full causal forward over all of `input`'s rows, composed purely from
/// `gemm_ref` calls at the policy's per-layer per-projection formats plus
/// the model's f32 glue. Row `r` only ever attends positions `0..=r`
/// (masked probabilities are exact 0.0), so a prefix of this output is the
/// oracle for a shorter prefill and row `t + k` is the oracle for the
/// k-th decode step.
fn oracle_causal(
    spec: &ModelSpec,
    weights: &[RefLayer],
    policy: &PrecisionPolicy,
    input: &[f32],
) -> Vec<f32> {
    let d = spec.d_model;
    let rows = input.len() / d;
    let hd = spec.head_dim();
    let heads = spec.heads;
    let kv_heads = spec.kv_heads;
    let kv_dim = kv_heads * hd;
    let qkv_cols = d + 2 * kv_dim;
    let act = policy.activation();
    let scale = 1.0 / (hd as f32).sqrt();

    let mut x = input.to_vec();
    for (li, l) in weights.iter().enumerate() {
        let lp = policy.layer(li);
        // Attention at (qkv.w x act), scores/context at (act x act).
        let xn = rms_norm(&x, d);
        let qkv = ref_gemm(&xn, act, &l.wqkv, lp.qkv.w, rows, d, qkv_cols);
        let mut ctx = vec![0f32; rows * d];
        for h in 0..heads {
            let kvh = h * kv_heads / heads;
            let mut q_h = vec![0f32; rows * hd];
            let mut k_t = vec![0f32; hd * rows];
            let mut v_h = vec![0f32; rows * hd];
            for r in 0..rows {
                for c in 0..hd {
                    q_h[r * hd + c] = qkv[r * qkv_cols + h * hd + c];
                    k_t[c * rows + r] = qkv[r * qkv_cols + d + kvh * hd + c];
                    v_h[r * hd + c] = qkv[r * qkv_cols + d + kv_dim + kvh * hd + c];
                }
            }
            let mut scores = ref_gemm(&q_h, act, &k_t, act, rows, hd, rows);
            for s in scores.iter_mut() {
                *s *= scale;
            }
            for r in 0..rows {
                for s in scores[r * rows + r + 1..(r + 1) * rows].iter_mut() {
                    *s = f32::NEG_INFINITY;
                }
            }
            softmax_rows(&mut scores, rows);
            let ctx_h = ref_gemm(&scores, act, &v_h, act, rows, rows, hd);
            for r in 0..rows {
                ctx[r * d + h * hd..r * d + (h + 1) * hd]
                    .copy_from_slice(&ctx_h[r * hd..(r + 1) * hd]);
            }
        }
        let attn = ref_gemm(&ctx, act, &l.wo, lp.out.w, rows, d, d);
        add_in_place(&mut x, &attn);
        // FFN at (gate_up.w / down.w x act); the gate shares gate_up's format.
        let xn = rms_norm(&x, d);
        let mut hmid = ref_gemm(&xn, act, &l.w_up, lp.gate_up.w, rows, d, spec.d_ff);
        match &l.w_gate {
            Some(wg) => {
                let g = ref_gemm(&xn, act, wg, lp.gate_up.w, rows, d, spec.d_ff);
                for (hv, gv) in hmid.iter_mut().zip(&g) {
                    *hv *= silu(*gv);
                }
            }
            None => {
                for hv in hmid.iter_mut() {
                    *hv = gelu(*hv);
                }
            }
        }
        let ffn = ref_gemm(&hmid, act, &l.w_down, lp.down.w, rows, spec.d_ff, d);
        add_in_place(&mut x, &ffn);
    }
    x
}

fn assert_bits_eq(got: &[f32], want: &[f32], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{tag}: element {i} differs: {g} vs {w}"
        );
    }
}

/// Seeded input rows in the same quantizable range the weights use.
fn test_input(spec: &ModelSpec, rows: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..rows * spec.d_model).map(|_| (rng.gauss() * 0.5) as f32).collect()
}

/// Prefill `t` rows, then decode the rest one row at a time, asserting
/// every output row bitwise against the `gemm_ref`-composed causal oracle.
fn assert_mixed_policy_bit_exact(spec: ModelSpec, tag: &str) {
    let seed = 0xF1E8_0001;
    let policy = mixed_policy();
    assert_eq!(spec.layers, 2, "{tag}: the mixed policy routes two layers");
    let model = NativeModel::synthesize(spec.clone(), seed);
    let weights = synth_ref(&spec, seed);

    let (t, n) = (5usize, 8usize);
    let input = test_input(&spec, n, 0xD00D);
    // Width-t oracle for the prefill rows; full-width for decode rows (the
    // masked tail beyond each decode position contributes exact zeros, as
    // the engine's own decode-vs-prefill contract requires).
    let d = spec.d_model;
    let oracle_pre = oracle_causal(&spec, &weights, &policy, &input[..t * d]);
    let oracle_full = oracle_causal(&spec, &weights, &policy, &input);

    let cache = WeightCache::new();
    let mut kv = KvCache::new(&spec, policy.activation());
    let pre = model.forward_prefill(&input[..t * d], &policy, &cache, &mut kv).unwrap();
    assert_bits_eq(&pre, &oracle_pre, &format!("{tag}: prefill"));
    assert!(
        pre.iter().any(|v| *v != 0.0),
        "{tag}: mixed-policy output must be nonzero (INT8 down keeps signal)"
    );
    for k in 0..n - t {
        let row = &input[(t + k) * d..(t + k + 1) * d];
        let out = model.forward_decode(row, &policy, &cache, &mut kv).unwrap();
        assert_bits_eq(
            &out,
            &oracle_full[(t + k) * d..(t + k + 1) * d],
            &format!("{tag}: decode step {k}"),
        );
    }
    assert_eq!(kv.repack_count(), 0, "{tag}: policy serving must never repack KV");
}

#[test]
fn mixed_policy_forward_is_bit_exact_mha() {
    let spec = ModelSpec {
        seq: 8,
        layers: 2,
        d_model: 32,
        d_ff: 64,
        heads: 2,
        kv_heads: 2,
        gated_ffn: false,
        name: "mha-e2e",
    };
    assert_mixed_policy_bit_exact(spec, "mha");
}

#[test]
fn mixed_policy_forward_is_bit_exact_gqa_swiglu() {
    let spec = ModelSpec {
        seq: 8,
        layers: 2,
        d_model: 32,
        d_ff: 64,
        heads: 4,
        kv_heads: 2,
        gated_ffn: true,
        name: "gqa-e2e",
    };
    assert_mixed_policy_bit_exact(spec, "gqa");
}

#[test]
fn searched_policy_is_digest_stable_and_round_trips() {
    let spec = ModelSpec::tiny();
    let model = NativeModel::synthesize(spec.clone(), 0xF1E81B);
    let cfg = SearchConfig::default();
    let act = fmt("e3m2");
    let a = search_policy(&model, "searched-tiny", act, &cfg);
    let b = search_policy(&model, "searched-tiny", act, &cfg);
    assert_eq!(a.digest(), b.digest(), "search must be deterministic");
    assert_eq!(a.to_json(), b.to_json());
    let parsed = PrecisionPolicy::parse_json(&a.to_json()).expect("searched policy parses back");
    assert_eq!(parsed, a, "policy JSON round-trips losslessly");
    assert_eq!(parsed.digest(), a.digest());
}

#[test]
fn one_checkpoint_serves_two_named_policies_in_one_run() {
    let spec = ModelSpec::tiny();
    let uniform = PrecisionPair::of_bits(6, 6).into_policy();
    let mixed = Arc::new(mixed_policy());
    assert_ne!(uniform.digest(), mixed.digest());

    let scenario = Scenario {
        seed: 7,
        sessions: 6,
        arrival: Arrival::Closed { concurrency: 3, think_s: 0.0 },
        prefill_len: Dist::Uniform(2, 6),
        decode_steps: Dist::Fixed(3),
        policies: vec![uniform.clone(), mixed.clone()],
        shared_prefix: 0,
    };
    let recorder = Recorder::enabled();
    let executor = NativeExecutor::new().with_model(spec.clone(), 0xF1E81B);
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_streak: 4,
            },
            sim_config: flexibit::sim::mobile_a(),
            sim_model: spec.clone(),
            recorder: recorder.clone(),
            drift: None,
            resilience: Resilience::default(),
            kv_pool: None,
        },
        Box::new(executor),
    );
    let mut rep = run(&server, &spec, &scenario, Duration::from_secs(120));
    rep.metrics = server.shutdown();
    assert!(!rep.timed_out);
    assert_eq!(rep.counts.failed, 0);
    assert_eq!(rep.counts.completed, 6 * 4, "1 prefill + Fixed(3) decodes per session");

    // One checkpoint, two named policies: each distinct digest gets exactly
    // one co-simulated cost entry in the v3 report.
    assert_eq!(rep.policy_costs.len(), 2);
    let names: Vec<&str> = rep.policy_costs.iter().map(|c| c.name.as_str()).collect();
    assert!(names.contains(&"[6,6]") && names.contains(&"mixed-e2e"), "{names:?}");
    assert_ne!(rep.policy_costs[0].digest, rep.policy_costs[1].digest);
    for c in &rep.policy_costs {
        assert!(c.seconds > 0.0 && c.energy_j > 0.0, "co-sim cost for {}", c.name);
    }
    let j = rep.json();
    assert!(j.contains("\"name\":\"mixed-e2e\""));
    assert!(j.contains("\"name\":\"[6,6]\""));

    // The drift ledger stays balanced and keys on policy labels.
    let m = &rep.metrics;
    assert_eq!(m.drift.audited() + m.drift.skipped(), m.batches_executed);
    let dr = m.drift_report();
    assert!(dr.contains("\"pair\":\"[6,6]\""), "{dr}");
    assert!(dr.contains("\"pair\":\"mixed-e2e\""), "{dr}");

    // Zero-repack serving: every decode adopted cached K/V codes in place.
    assert_eq!(recorder.counter(Counter::KvRepack), 0, "no KV repacks under policies");
    assert!(recorder.counter(Counter::KvAdopt) > 0, "decode must adopt cached K/V");
}
