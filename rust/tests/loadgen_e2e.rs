//! End-to-end tests of the traffic harness + drift auditor: seeded load
//! against the real native engine is bit-reproducible and fully audited,
//! and a deliberately mis-calibrated simulator config trips the drift gate.

use flexibit::coordinator::{
    Batch, BatchPolicy, FnExecutor, Metrics, Phase, Resilience, Server, ServerConfig,
};
use flexibit::kernels::NativeExecutor;
use flexibit::loadgen::{run, Arrival, Dist, LoadReport, Scenario};
use flexibit::obs::{DriftBound, Recorder};
use flexibit::sim::AcceleratorConfig;
use flexibit::workload::{IntoPolicy, ModelSpec, PrecisionPair};
use std::time::Duration;

fn pairs() -> Vec<PrecisionPair> {
    vec![PrecisionPair::of_bits(6, 6), PrecisionPair::of_bits(8, 8)]
}

/// Mixed prefill/decode over two precision pairs — the CI scenario shape.
fn scenario(seed: u64) -> Scenario {
    Scenario {
        seed,
        sessions: 6,
        arrival: Arrival::Closed { concurrency: 3, think_s: 0.0 },
        prefill_len: Dist::Uniform(2, 6),
        decode_steps: Dist::Fixed(3),
        policies: pairs().into_iter().map(|p| p.into_policy()).collect(),
        shared_prefix: 0,
    }
}

/// Run one seeded scenario against the real native engine; metrics are
/// refreshed post-shutdown so trailing End batches are folded in.
fn native_run(seed: u64) -> LoadReport {
    let spec = ModelSpec::tiny();
    let executor = NativeExecutor::new().with_model(spec.clone(), 0xF1E81B);
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_streak: 4,
            },
            sim_config: flexibit::sim::mobile_a(),
            sim_model: spec.clone(),
            recorder: Recorder::disabled(),
            drift: None,
            resilience: Resilience::default(),
            kv_pool: None,
        },
        Box::new(executor),
    );
    let mut report = run(&server, &spec, &scenario(seed), Duration::from_secs(120));
    report.metrics = server.shutdown();
    report
}

#[test]
fn seeded_load_is_bit_reproducible_on_the_native_engine() {
    let a = native_run(7);
    let b = native_run(7);
    assert!(!a.timed_out && !b.timed_out);
    // Same seed => same request schedule (digest over the full plan) and
    // the same completion counts, token for token.
    assert_eq!(a.digest, b.digest);
    assert_eq!(a.counts.submitted, b.counts.submitted);
    assert_eq!(a.counts.completed, b.counts.completed);
    assert_eq!(a.counts.prefill_tokens, b.counts.prefill_tokens);
    assert_eq!(a.counts.decode_tokens, b.counts.decode_tokens);
    assert_eq!(a.counts.completed, 6 * 4, "1 prefill + Fixed(3) decodes per session");
    assert_eq!(a.counts.failed, 0);
    // A different seed reshuffles the schedule.
    assert_ne!(native_run(8).digest, a.digest);

    // Per-phase latency reporting comes from real histogram data.
    let m = &a.metrics;
    assert_eq!(m.prefill_latency.count(), 6);
    assert_eq!(m.decode_latency.count(), 18);
    for h in [&m.prefill_latency, &m.decode_latency, &m.latency] {
        let (p50, p95, p99) = (h.quantile(0.50), h.quantile(0.95), h.quantile(0.99));
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
    }
    assert!(a.wall_s > 0.0 && m.throughput_rps(a.wall_s) > 0.0, "goodput from the run");

    // The machine-readable report carries the phase split and the digest.
    let j = a.json();
    assert!(j.contains("\"schema\":\"flexibit.loadgen.v3\""));
    assert!(j.contains("\"policy_costs\":[{\"name\":\"[6,6]\","));
    assert!(j.contains("\"faults\":null"));
    assert_eq!(a.counts.output_digest, b.counts.output_digest, "outputs bit-identical");
    assert!(j.contains(&format!("\"digest\":\"{}\"", a.digest)));
    assert!(j.contains("\"prefill\":{\"count\":6"));
    assert!(j.contains("\"decode\":{\"count\":18"));
    assert_eq!(j.matches('{').count(), j.matches('}').count(), "balanced: {j}");
}

#[test]
fn drift_audit_has_one_entry_per_executed_batch_under_load() {
    let rep = native_run(7);
    let d = &rep.metrics.drift;
    assert!(d.audited() > 0, "drift histograms must be populated");
    assert_eq!(
        d.audited() + d.skipped(),
        rep.metrics.batches_executed,
        "every executed batch lands in the audit exactly once"
    );
    assert_eq!(d.total_samples(), d.audited());
    assert_eq!(d.violations(), 0, "no bound configured");
    // Both precision pairs produced their own ratio populations.
    let report = rep.metrics.drift_report();
    for pair in pairs() {
        assert!(
            report.contains(&format!("\"pair\":\"{}\"", pair.label())),
            "missing {} in {report}",
            pair.label()
        );
    }
}

/// A stub executor whose measured cost is an exact deterministic function
/// of the batch's token content — so the measured/predicted ratio depends
/// only on shapes, and a mis-calibrated simulator is unambiguously visible.
fn token_cost_executor() -> FnExecutor<impl FnMut(&Batch) -> Result<f64, String> + Send> {
    FnExecutor(|b: &Batch| -> Result<f64, String> {
        let tokens: usize = b
            .requests
            .iter()
            .map(|r| match r.phase {
                Phase::Decode => 1,
                Phase::End => 0,
                Phase::Prefill => r.dims.first().copied().unwrap_or(1),
            })
            .sum();
        Ok(1e-4 * tokens as f64)
    })
}

fn stub_model() -> ModelSpec {
    ModelSpec {
        seq: 8,
        layers: 1,
        d_model: 32,
        d_ff: 64,
        heads: 2,
        kv_heads: 2,
        gated_ffn: false,
        name: "tiny",
    }
}

fn gated_run(sim_config: AcceleratorConfig, drift: Option<DriftBound>) -> Metrics {
    let spec = stub_model();
    let server = Server::start(
        ServerConfig {
            policy: BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                max_streak: 4,
            },
            sim_config,
            sim_model: spec.clone(),
            recorder: Recorder::disabled(),
            drift,
            resilience: Resilience::default(),
            kv_pool: None,
        },
        Box::new(token_cost_executor()),
    );
    let rep = run(&server, &spec, &scenario(7), Duration::from_secs(60));
    assert!(!rep.timed_out);
    server.shutdown()
}

#[test]
fn drift_gate_trips_on_a_miscalibrated_sim_config() {
    // Calibrate: observe the honest ratio range, no gate.
    let calib = gated_run(flexibit::sim::mobile_a(), None);
    assert!(calib.drift.audited() > 0);
    let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
    for (_, e) in calib.drift.keys() {
        lo = lo.min(e.min());
        hi = hi.max(e.max());
    }
    assert!(lo.is_finite() && lo > 0.0 && hi >= lo);
    // A 10x-slack band around the calibration: the same workload against
    // the same sim config stays inside it (batch ratios are weighted means
    // of per-request ratios, so batching nondeterminism cannot escape a
    // 10x margin around the observed extremes).
    let band = Some((lo / 10.0, hi * 10.0));
    let good = gated_run(
        flexibit::sim::mobile_a(),
        Some(DriftBound { band, max_spread: None, warmup: 0 }),
    );
    assert_eq!(good.drift.violations(), 0, "calibrated config must pass its own band");
    assert!(good.drift.audited() > 0);

    // Mis-calibrate the analytical model: claim the accelerator is 1e7x
    // faster across compute, DRAM, and NoC. Predicted cost collapses, every
    // ratio inflates ~1e7x, and the gate must fire.
    let mut lying = flexibit::sim::mobile_a();
    lying.clock_hz *= 1e7;
    lying.offchip_bw *= 1e7;
    lying.noc_bw *= 1e7;
    let bad = gated_run(lying, Some(DriftBound { band, max_spread: None, warmup: 0 }));
    assert!(
        bad.drift.violations() > 0,
        "a 1e7x sim mis-calibration must trip the drift gate"
    );
    assert!(bad.drift.last_violation().unwrap().contains("outside band"));
    // The gate reports loudly but does not drop traffic.
    assert_eq!(bad.requests_completed, calib.requests_completed);
}
