//! End-to-end integration: AOT artifacts → PJRT runtime → numerics.
//!
//! This target only builds with `--features pjrt` (see Cargo.toml
//! `required-features`): the default offline build has no `xla` crate and
//! no Python toolchain, so tier-1 `cargo test -q` must not depend on it.
//! Even with the feature, the tests require `make artifacts` to have run;
//! they skip (pass trivially with a notice) when the artifacts directory
//! is absent so `cargo test --features pjrt` works in a fresh checkout.

use flexibit::runtime::{artifacts_dir, load_block_weights, InputBuf, Runtime};
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn json_f32_array(text: &str, key: &str) -> Vec<f32> {
    let pat = format!("\"{key}\":");
    let start = text.find(&pat).unwrap() + pat.len();
    let rest = &text[start..];
    let lb = rest.find('[').unwrap();
    let rb = rest[lb..].find(']').unwrap() + lb;
    rest[lb + 1..rb].split(',').filter_map(|s| s.trim().parse::<f32>().ok()).collect()
}

#[test]
fn loads_all_artifacts() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new().expect("PJRT CPU client");
    let loaded = rt.load_artifacts_dir(&dir).expect("load artifacts");
    // 4 block + 4 gemm + the model.hlo.txt alias.
    assert!(loaded.len() >= 8, "expected >= 8 artifacts, got {loaded:?}");
    for b in [4, 5, 6, 8] {
        assert!(rt.has_model(&format!("block_w{b}")));
        assert!(rt.has_model(&format!("gemm_w{b}")));
    }
}

#[test]
fn block_artifacts_match_python_golden_output() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new().unwrap();
    rt.load_artifacts_dir(&dir).unwrap();
    for bits in [4u32, 5, 6, 8] {
        let name = format!("block_w{bits}");
        let io = std::fs::read_to_string(dir.join(format!("{name}.io.json"))).unwrap();
        let input = json_f32_array(&io, "input");
        let expect = json_f32_array(&io, "output");
        let weights = load_block_weights(&dir.join(format!("{name}.weights.json"))).unwrap();
        let mut inputs = vec![InputBuf::F32(&input, vec![32, 128])];
        for (words, shape) in &weights {
            inputs.push(InputBuf::U32(words, shape.clone()));
        }
        let out = rt.execute_mixed(&name, &inputs).unwrap();
        assert_eq!(out[0].len(), expect.len(), "{name} output length");
        let max_err = out[0]
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "{name}: PJRT vs Python eager max err {max_err}");
    }
}

#[test]
fn gemm_artifact_with_runtime_weights_matches_rust_golden_model() {
    // The full three-layer consistency check: quantize weights in Rust
    // (arith golden model), pack them with the same per-column layout the
    // Python quantizer uses, run the AOT Pallas GEMM through PJRT, and
    // compare against the Rust golden dequantize-matmul.
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::new().unwrap();
    rt.load_artifacts_dir(&dir).unwrap();

    use flexibit::arith::{decode, encode, Format};
    let (m, k, n) = (32usize, 128usize, 128usize);
    for bits in [4u32, 5, 6, 8] {
        let fmt = Format::default_fp(bits);
        let mut rng = flexibit::util::Rng::new(99 + bits as u64);
        // Random weights, quantized via the Rust golden encode.
        let w_f: Vec<f64> = (0..k * n).map(|_| rng.gauss() * 0.3).collect();
        let codes: Vec<u32> = w_f.iter().map(|&v| encode(v, fmt)).collect();
        // Per-column bit packing (quant.pack_columns layout).
        let wpc = (k * bits as usize).div_ceil(32);
        let mut words = vec![0u32; n * wpc];
        for col in 0..n {
            for ki in 0..k {
                let code = codes[ki * n + col] as u64;
                let bit = ki * bits as usize;
                let (wi, off) = (bit / 32, bit % 32);
                words[col * wpc + wi] |= (code << off) as u32;
                if off + bits as usize > 32 {
                    words[col * wpc + wi + 1] |= (code >> (32 - off)) as u32;
                }
            }
        }
        let acts: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32 * 0.5).collect();

        // PJRT execution with runtime-supplied packed weights.
        let name = format!("gemm_w{bits}");
        let out = rt
            .execute_u32_weights(&name, &acts, &[m, k], &words, &[n, wpc])
            .expect("gemm artifact executes");

        // Rust golden: dequantize + matmul in f64.
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for ki in 0..k {
                    acc += acts[i * k + ki] as f64 * decode(codes[ki * n + j], fmt);
                }
                let got = out[i * n + j] as f64;
                let tol = 1e-3 * (1.0 + acc.abs());
                assert!(
                    (got - acc).abs() < tol,
                    "w{bits} [{i},{j}]: pjrt {got} vs golden {acc}"
                );
            }
        }
    }
}
