//! Cross-module integration tests that need no artifacts: PE array vs
//! golden GEMM, compiler/PE consistency, simulator invariants across the
//! whole precision grid, and the paper's qualitative claims as assertions.

use flexibit::arith::{decode, dot_exact, encode, Format};
use flexibit::baselines::{
    Accel, BitFusionAccel, BitModAccel, CambriconPAccel, FlexiBitAccel, TensorCoreAccel,
};
use flexibit::compiler;
use flexibit::pe::{Pe, PeConfig};
use flexibit::sim::{all_configs, cloud_b, simulate_model};
use flexibit::util::{property, Rng};
use flexibit::workload::{all_models, bert_base, PrecisionPair};

/// A small GEMM through PE windows (outer-product tiles + accumulate) must
/// equal the golden dequantize-matmul.
#[test]
fn pe_array_gemm_matches_golden() {
    let a_fmt = Format::default_fp(6);
    let w_fmt = Format::default_fp(5);
    let (m, k, n) = (3usize, 8usize, 4usize);
    let mut rng = Rng::new(17);
    let acts: Vec<u32> = rng.codes(m * k, a_fmt.bits());
    let wgts: Vec<u32> = rng.codes(k * n, w_fmt.bits());
    let mut pe = Pe::new(PeConfig::default());
    for i in 0..m {
        for j in 0..n {
            let a_row: Vec<u32> = (0..k).map(|kk| acts[i * k + kk]).collect();
            let w_col: Vec<u32> = (0..k).map(|kk| wgts[kk * n + j]).collect();
            let got = pe.dot(&a_row, a_fmt, &w_col, w_fmt);
            let expect = dot_exact(&a_row, a_fmt, &w_col, w_fmt);
            assert_eq!(got, expect, "element [{i},{j}]");
        }
    }
}

/// Property: for random formats and windows, every PE product matches the
/// golden model (the RTL-verification stand-in, at integration level).
#[test]
fn pe_products_match_golden_randomized() {
    property(2024, 60, |rng| {
        let a_fmt = Format::fp(1 + rng.below(5) as u8, rng.below(8) as u8);
        let w_fmt = Format::fp(1 + rng.below(5) as u8, rng.below(8) as u8);
        let mut pe = Pe::new(PeConfig::default());
        let n_a = pe.cfg.operands_per_window(a_fmt).max(1);
        let n_w = pe.cfg.operands_per_window(w_fmt).max(1);
        let acts = rng.codes(n_a, a_fmt.bits());
        let wgts = rng.codes(n_w, w_fmt.bits());
        let win = pe.multiply_window(&acts, a_fmt, &wgts, w_fmt);
        for (oid, p) in win.products.iter().enumerate() {
            let (wi, ai) = (oid / win.n_acts, oid % win.n_acts);
            let golden = flexibit::arith::mul_exact(acts[ai], a_fmt, wgts[wi], w_fmt);
            assert_eq!(p.value(), golden.value(), "{a_fmt}x{w_fmt}");
        }
    });
}

/// Property: encode/decode round-trips for random formats (golden model
/// self-consistency over the full format space).
#[test]
fn encode_decode_roundtrip_randomized() {
    property(5150, 200, |rng| {
        let fmt = Format::fp(1 + rng.below(8) as u8, rng.below(11) as u8);
        let code = rng.code(fmt.bits());
        let v = decode(code, fmt);
        if v != 0.0 {
            assert_eq!(encode(v, fmt), code, "{fmt} code {code}");
        }
    });
}

/// The compiler's mults_per_cycle must equal what the PE actually produces
/// for full windows, across the whole practical format grid.
#[test]
fn compiler_throughput_matches_pe_behavior() {
    let cfg = PeConfig::default();
    for e in 1..=5u8 {
        for m in 0..=10u8 {
            let fmt = Format::fp(e, m);
            if fmt.bits() > 24 {
                continue;
            }
            let bundle = compiler::compile(&cfg, fmt, fmt);
            let mut pe = Pe::new(cfg);
            let n = cfg.operands_per_window(fmt).max(1);
            let mut rng = Rng::new((e as u64) << 8 | m as u64);
            let acts = rng.codes(n, fmt.bits());
            let wgts = rng.codes(n, fmt.bits());
            let win = pe.multiply_window(&acts, fmt, &wgts, fmt);
            // The compiler's per-cycle promise never exceeds what a full
            // register window supplies (a window may take several cycles
            // when a narrower resource — e.g. FBEA lanes — binds).
            assert!(
                bundle.mults_per_cycle <= win.products.len().max(1),
                "e{e}m{m}: compiler promised {} but window holds {}",
                bundle.mults_per_cycle,
                win.products.len()
            );
        }
    }
}

/// Simulator sanity across the whole campaign grid: positive latencies,
/// energies, and the monotonicity the paper's story depends on.
#[test]
fn campaign_grid_invariants() {
    let fb = FlexiBitAccel::new();
    let tc = TensorCoreAccel::new();
    let bf = BitFusionAccel::new();
    let pairs: Vec<PrecisionPair> = [(16, 16), (8, 8), (6, 16), (6, 6), (4, 4)]
        .into_iter()
        .map(|(w, a)| PrecisionPair::of_bits(w, a))
        .collect();
    for cfg in all_configs() {
        for model in all_models() {
            for &pair in &pairs {
                let r_fb = simulate_model(&fb, &cfg, &model, pair);
                let r_tc = simulate_model(&tc, &cfg, &model, pair);
                let r_bf = simulate_model(&bf, &cfg, &model, pair);
                for r in [&r_fb, &r_tc, &r_bf] {
                    assert!(r.seconds > 0.0 && r.seconds.is_finite());
                    assert!(r.energy_j > 0.0 && r.energy_j.is_finite());
                }
                // FlexiBit is never slower than the padding baselines
                // (equal-or-better by construction of zero padding waste).
                assert!(
                    r_fb.seconds <= r_tc.seconds * 1.0001,
                    "{} {} {}: FB {} > TC {}",
                    cfg.name,
                    model.name,
                    pair.label(),
                    r_fb.seconds,
                    r_tc.seconds
                );
                assert!(r_fb.seconds <= r_bf.seconds * 1.0001);
            }
        }
    }
}

/// The §5.3.3 ordering: bit-serial architectures trade latency for power.
#[test]
fn bit_serial_tradeoff_ordering() {
    let fb = FlexiBitAccel::new();
    let cp = CambriconPAccel::new();
    let bm = BitModAccel::new();
    let cfg = cloud_b();
    let pair = PrecisionPair::of_bits(6, 16);
    let model = bert_base();
    let r_fb = simulate_model(&fb, &cfg, &model, pair);
    let r_cp = simulate_model(&cp, &cfg, &model, pair);
    let r_bm = simulate_model(&bm, &cfg, &model, pair);
    // Latency: FlexiBit < BitMoD < Cambricon-P.
    assert!(r_fb.seconds < r_bm.seconds && r_bm.seconds < r_cp.seconds);
    // Energy: bit-serial lower.
    assert!(r_cp.energy_j < r_fb.energy_j);
    assert!(r_bm.energy_j < r_fb.energy_j);
    // EDP: FlexiBit best (the paper's conclusion).
    assert!(r_fb.edp() < r_bm.edp() && r_fb.edp() < r_cp.edp());
}

/// Reconfiguration cost stays under the paper's < 100-cycle claim for all
/// practical register widths.
#[test]
fn reconfiguration_cost_bound() {
    for rw in [16, 20, 24, 28, 32] {
        let cfg = PeConfig::with_reg_width(rw);
        assert!(compiler::reconfiguration_cycles(&cfg) < 100, "reg_width {rw}");
    }
}
