//! Native bit-packed GEMM engine: golden-equivalence and serving
//! integration tests.
//!
//! The contract under test: for **every** supported precision pair —
//! including non-power-of-two widths — the tiled/threaded kernel in
//! `flexibit::kernels` is bit-identical to `arith::gemm_ref`, and the
//! reference itself tracks the exact integer golden model (`dot_exact`)
//! within f32 accumulation error. Plus: end-to-end serving through
//! `NativeExecutor` with zero artifacts on disk.

use flexibit::arith::{decode, dot_exact, gemm_ref, Format, FpFormat, PackedTensor};
use flexibit::coordinator::{BatchPolicy, Request, Resilience, Server, ServerConfig, StreamDriver};
use flexibit::kernels::{
    extract_codes, gemm, gemm_default, gemm_tiled, gemm_with_panels, int_fast_path_exact,
    int_fast_path_exact_with, Decoder, GemmConfig, KvCache, NativeExecutor, NativeModel,
    PackedMatrix, WeightCache, WeightPanels,
};
use flexibit::util::{property, Rng};
use flexibit::workload::{IntoPolicy, ModelSpec, PrecisionPair};
use std::time::{Duration, Instant};

/// The evaluation formats: FP4/FP5/FP6 (both variants)/FP8 (E4M3 + E5M2),
/// INT4/INT8 — every cross of these is a supported precision pair.
fn formats() -> Vec<Format> {
    vec![
        Format::Fp(FpFormat::FP4_E2M1),
        Format::Fp(FpFormat::FP5_E2M2),
        Format::Fp(FpFormat::FP6_E3M2),
        Format::Fp(FpFormat::FP6_E2M3),
        Format::Fp(FpFormat::FP8_E4M3),
        Format::Fp(FpFormat::FP8_E5M2),
        Format::int(4),
        Format::int(8),
    ]
}

fn assert_kernel_matches_golden(
    rng: &mut Rng,
    a_fmt: Format,
    w_fmt: Format,
    m: usize,
    k: usize,
    n: usize,
    cfg: &GemmConfig,
) {
    let a_codes = rng.codes(m * k, a_fmt.bits());
    let w_codes = rng.codes(k * n, w_fmt.bits());
    let a = PackedMatrix::from_codes(&a_codes, m, k, a_fmt);
    let w = PackedMatrix::from_codes(&w_codes, k, n, w_fmt);
    let got = gemm(&a, &w, cfg);
    let want = gemm_ref(&a_codes, a_fmt, &w_codes, w_fmt, m, k, n);
    assert_eq!(got, want, "{a_fmt}x{w_fmt} {m}x{k}x{n} (cfg {cfg:?})");
}

/// Every format cross, random tensors: kernel == golden reference, exactly.
#[test]
fn all_precision_crosses_match_golden_exactly() {
    let mut rng = Rng::new(0xC0FFEE);
    let cfg = GemmConfig::default();
    for &a_fmt in &formats() {
        for &w_fmt in &formats() {
            let (m, k, n) = (5, 33, 9); // off-tile on every axis
            assert_kernel_matches_golden(&mut rng, a_fmt, w_fmt, m, k, n, &cfg);
        }
    }
}

/// Property sweep: random formats (arbitrary e/m, any width 3..=16 plus
/// INTs), random non-multiple-of-tile shapes, random tile configs.
#[test]
fn randomized_formats_shapes_and_tilings() {
    property(0xF1E8, 60, |rng| {
        let pick = |rng: &mut Rng| -> Format {
            if rng.below(4) == 0 {
                Format::int(2 + rng.below(9) as u8)
            } else {
                Format::fp(1 + rng.below(5) as u8, rng.below(8) as u8)
            }
        };
        let a_fmt = pick(rng);
        let w_fmt = pick(rng);
        let m = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(90) as usize;
        let n = 1 + rng.below(70) as usize;
        let cfg = GemmConfig {
            kc: 1 + rng.below(80) as usize,
            nc: 1 + rng.below(80) as usize,
            threads: 1 + rng.below(4) as usize,
        };
        let mut case_rng = Rng::new(rng.next_u64());
        assert_kernel_matches_golden(&mut case_rng, a_fmt, w_fmt, m, k, n, &cfg);
    });
}

/// Multi-lane decoder vs the scalar per-element reference, across bit
/// widths {1, 3, 5, 6, 7, 11, 12, 16} at offsets that straddle `u64` word
/// boundaries. Width 1 has no [`Format`], so it runs through the raw
/// [`extract_codes`] lane extractor against hand-computed bits; the rest
/// sweep real formats through both decode paths.
#[test]
fn multi_lane_decoder_straddle_sweep() {
    let mut rng = Rng::new(0xDEC0DE);

    // Width 1: raw extractor vs per-bit arithmetic.
    let words: Vec<u64> = (0..5).map(|_| rng.next_u64()).collect();
    for bit0 in [0usize, 1, 62, 63, 64, 127, 129] {
        let len = words.len() * 64 - bit0;
        let mut got = vec![0u32; len];
        extract_codes(&words, bit0, 1, &mut got);
        for (i, &g) in got.iter().enumerate() {
            let b = bit0 + i;
            assert_eq!(g, ((words[b / 64] >> (b % 64)) & 1) as u32, "width 1 bit {b}");
        }
    }

    // Widths {3, 5, 6, 7, 11, 12, 16} through real formats. Column counts
    // are chosen so rows land at non-word-aligned bit offsets.
    let fmts = [
        Format::fp(1, 1), // 3 bits
        Format::Fp(FpFormat::FP5_E2M2), // 5
        Format::Fp(FpFormat::FP6_E3M2), // 6
        Format::fp(3, 3), // 7
        Format::int(11),
        Format::int(12),
        Format::Fp(FpFormat::FP16), // 16
    ];
    for fmt in fmts {
        let (r, c) = (4, 85);
        let codes = rng.codes(r * c, fmt.bits());
        let m = PackedMatrix::from_codes(&codes, r, c, fmt);
        let dec = Decoder::new(fmt);
        for row in 0..r {
            for col0 in [0usize, 1, 9, 10, 11, 20, 21, 42, 63, 64, 84] {
                let len = c - col0;
                let mut fast = vec![0f32; len];
                let mut slow = vec![0f32; len];
                m.decode_row_range(row, col0, &dec, &mut fast);
                m.decode_row_range_scalar(row, col0, &dec, &mut slow);
                assert_eq!(fast, slow, "{fmt} row {row} col0 {col0}");
            }
        }
    }
}

/// The INT i32 fast path is tile/thread-invariant and bit-identical to
/// `gemm_ref`, with and without decoded weight panels; an out-of-guard
/// depth falls back to the f32 path and still matches.
#[test]
fn int_fast_path_tile_invariance() {
    let mut rng = Rng::new(0x1272);
    let i4 = Format::int(4);
    let (m, k, n) = (7, 129, 43);
    assert!(int_fast_path_exact(i4, i4, k), "case must exercise the fast path");
    let a_codes = rng.codes(m * k, i4.bits());
    let w_codes = rng.codes(k * n, i4.bits());
    let a = PackedMatrix::from_codes(&a_codes, m, k, i4);
    let w = PackedMatrix::from_codes(&w_codes, k, n, i4);
    let want = gemm_ref(&a_codes, i4, &w_codes, i4, m, k, n);
    for (kc, nc, threads) in [(64, 64, 1), (1, 1, 1), (5, 9, 3), (128, 8, 2), (17, 128, 4)] {
        let cfg = GemmConfig { kc, nc, threads };
        assert_eq!(gemm(&a, &w, &cfg), want, "kc={kc} nc={nc} threads={threads}");
        let panels = WeightPanels::build(&w, kc, nc);
        assert_eq!(
            gemm_with_panels(&a, &w, &panels, &cfg),
            want,
            "panels kc={kc} nc={nc} threads={threads}"
        );
    }
    // Beyond the exact guard (int8 x int8, k > 1024): must fall back and
    // still match the f32 reference bit-for-bit.
    let i8f = Format::int(8);
    let (m2, k2, n2) = (3, 1100, 12);
    assert!(!int_fast_path_exact(i8f, i8f, k2));
    let a2c = rng.codes(m2 * k2, i8f.bits());
    let w2c = rng.codes(k2 * n2, i8f.bits());
    let a2 = PackedMatrix::from_codes(&a2c, m2, k2, i8f);
    let w2 = PackedMatrix::from_codes(&w2c, k2, n2, i8f);
    assert_eq!(
        gemm_default(&a2, &w2),
        gemm_ref(&a2c, i8f, &w2c, i8f, m2, k2, n2),
        "out-of-guard INT pair must fall back exactly"
    );
}

/// Decoded weight panels are bit-transparent for FP pairs too, whatever
/// tiling they were built with.
#[test]
fn weight_panels_bit_transparent() {
    let mut rng = Rng::new(0x9A7E1);
    let a_fmt = Format::Fp(FpFormat::FP6_E3M2);
    let w_fmt = Format::Fp(FpFormat::FP5_E2M2);
    let (m, k, n) = (5, 77, 39);
    let a_codes = rng.codes(m * k, a_fmt.bits());
    let w_codes = rng.codes(k * n, w_fmt.bits());
    let a = PackedMatrix::from_codes(&a_codes, m, k, a_fmt);
    let w = PackedMatrix::from_codes(&w_codes, k, n, w_fmt);
    let want = gemm_ref(&a_codes, a_fmt, &w_codes, w_fmt, m, k, n);
    let cfg = GemmConfig::default();
    for (kc, nc) in [(64, 64), (13, 6), (128, 128), (1, 39)] {
        let panels = WeightPanels::build(&w, kc, nc);
        assert_eq!(gemm_with_panels(&a, &w, &panels, &cfg), want, "kc={kc} nc={nc}");
    }
}

/// Edge shapes: single row/column/element, K=1, tall-skinny, wide-flat.
#[test]
fn edge_case_shapes() {
    let mut rng = Rng::new(0xED6E);
    let fp6 = Format::Fp(FpFormat::FP6_E3M2);
    let fp5 = Format::Fp(FpFormat::FP5_E2M2);
    let cfg = GemmConfig::default();
    for &(m, k, n) in
        &[(1, 1, 1), (1, 1, 129), (129, 1, 1), (1, 257, 1), (3, 64, 64), (64, 65, 63), (2, 7, 2)]
    {
        assert_kernel_matches_golden(&mut rng, fp6, fp5, m, k, n, &cfg);
    }
}

/// The f32 reference itself must track the exact fixed-point golden model.
#[test]
fn reference_tracks_exact_golden_model() {
    let mut rng = Rng::new(0x60);
    for &fmt in &[Format::Fp(FpFormat::FP6_E3M2), Format::int(8)] {
        let (m, k, n) = (3usize, 16usize, 4usize);
        let a = rng.codes(m * k, fmt.bits());
        let w = rng.codes(k * n, fmt.bits());
        let c = gemm_ref(&a, fmt, &w, fmt, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let a_row: Vec<u32> = (0..k).map(|kk| a[i * k + kk]).collect();
                let w_col: Vec<u32> = (0..k).map(|kk| w[kk * n + j]).collect();
                let exact = dot_exact(&a_row, fmt, &w_col, fmt);
                let scale: f64 = a_row
                    .iter()
                    .zip(&w_col)
                    .map(|(&ab, &wb)| (decode(ab, fmt) * decode(wb, fmt)).abs())
                    .sum::<f64>()
                    .max(1.0);
                let tol = scale * k as f64 * f32::EPSILON as f64;
                assert!(
                    (c[i * n + j] as f64 - exact).abs() <= tol,
                    "[{i},{j}] {fmt}: f32 {} vs exact {exact}",
                    c[i * n + j]
                );
            }
        }
    }
}

/// Quantize-then-pack path: f32 inputs end up identical to encode+pack.
#[test]
fn quantized_activations_roundtrip_through_kernel() {
    let mut rng = Rng::new(0xAC);
    let a_fmt = Format::Fp(FpFormat::FP8_E4M3);
    let w_fmt = Format::Fp(FpFormat::FP6_E3M2);
    let (m, k, n) = (4usize, 20usize, 6usize);
    let a_vals: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32 * 0.5).collect();
    let w_vals: Vec<f32> = (0..k * n).map(|_| rng.gauss() as f32 * 0.3).collect();
    let a = PackedMatrix::from_f32(&a_vals, m, k, a_fmt);
    let w = PackedMatrix::from_f32(&w_vals, k, n, w_fmt);
    let got = gemm_default(&a, &w);
    let want = gemm_ref(&a.codes(), a_fmt, &w.codes(), w_fmt, m, k, n);
    assert_eq!(got, want);
    // And the quantization itself is the arith encode (spot check).
    assert_eq!(a.get(0, 0), {
        let q = flexibit::arith::encode(a_vals[0] as f64, a_fmt);
        decode(q, a_fmt)
    });
}

/// End-to-end: the server drains a mixed-precision stream through the
/// native executor — including FP6xFP6 — with zero artifacts on disk, and
/// the weight cache packs once per (model, weight format).
#[test]
fn server_serves_mixed_precision_natively() {
    let spec = ModelSpec {
        name: "tiny-native-e2e",
        seq: 8,
        layers: 1,
        d_model: 32,
        d_ff: 64,
        heads: 2,
        gated_ffn: false,
        kv_heads: 2,
    };
    let executor = NativeExecutor::new().with_model(spec.clone(), 99);
    let cfg = ServerConfig {
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_streak: 4,
        },
        sim_config: flexibit::sim::mobile_a(),
        sim_model: spec.clone(),
        recorder: flexibit::obs::Recorder::disabled(),
        drift: None,
        resilience: Resilience::default(),
        kv_pool: None,
    };
    let server = Server::start(cfg, Box::new(executor));
    let pairs = [
        PrecisionPair::of_bits(6, 6),
        PrecisionPair::of_bits(5, 8),
        PrecisionPair::new(Format::int(4), Format::default_fp(16)),
    ];
    let n_requests = 12u64;
    let mut rng = Rng::new(5);
    for i in 0..n_requests {
        let input: Vec<f32> =
            (0..spec.seq * spec.d_model).map(|_| rng.gauss() as f32 * 0.5).collect();
        server.submit(Request::new(
            i,
            spec.name,
            pairs[(i % 3) as usize],
            input,
            vec![spec.seq, spec.d_model],
        ));
    }
    server.await_completed(n_requests, Duration::from_secs(30));
    let m = server.shutdown();
    assert_eq!(m.requests_completed, n_requests, "all native requests complete");
    assert!(m.batches_executed >= 3, "one batch per precision at least");
    assert!(m.host_exec_s > 0.0, "native execution accrues host time");
    assert!(m.sim_accel_s > 0.0 && m.sim_energy_j > 0.0, "co-simulation still runs");
}

/// Unknown model → the executor reports an error (and the server survives).
#[test]
fn executor_rejects_unknown_model() {
    use flexibit::coordinator::{Batch, Executor};
    let mut ex = NativeExecutor::new().with_model(ModelSpec::tiny(), 1);
    let batch = Batch {
        model: "unregistered".to_string(),
        policy: PrecisionPair::of_bits(6, 6).into_policy(),
        requests: vec![],
    };
    assert!(ex.execute(&batch).is_err());
    assert_eq!(ex.name(), "native");
}

/// **The decode-phase contract**: attending one new token against the KV
/// cache is bit-identical to re-running the full causal prefill over the
/// whole sequence — for FP x FP, FP x INT, and INT x INT precision pairs,
/// and for MHA plus both GQA grouping factors. The cache stores exactly the
/// quantized codes prefill produces, every GEMM keeps one ascending-k
/// accumulation chain per output element, and the causal softmax's masked
/// tail contributes exact zeros, so the incremental and recomputed float-op
/// sequences coincide.
#[test]
fn decode_is_bit_identical_to_full_prefill_recompute() {
    let pairs = [
        PrecisionPair::of_bits(6, 6), // FP6 x FP6 (paper headline)
        PrecisionPair::new(Format::Fp(FpFormat::FP8_E4M3), Format::int(4)), // E4M3 x INT4
        PrecisionPair::new(Format::int(8), Format::int(8)), // INT8 x INT8 (i32 fast path)
    ];
    let (t, s) = (5usize, 3usize); // prefill 5 tokens, then 3 decode steps
    for kv_heads in [4usize, 2, 1] {
        let spec = ModelSpec {
            name: "decode-bitident",
            seq: 16,
            layers: 2,
            d_model: 32,
            d_ff: 48,
            heads: 4,
            gated_ffn: true,
            kv_heads,
        };
        let d = spec.d_model;
        let model = NativeModel::synthesize(spec.clone(), 42);
        let mut rng = Rng::new(0xD3C0DE + kv_heads as u64);
        let input: Vec<f32> = (0..(t + s) * d).map(|_| rng.gauss() as f32 * 0.5).collect();
        for pair in pairs {
            // Fresh cache per case: panels/packs must not leak across specs.
            let cache = WeightCache::new();

            // Incremental: prefill the first t tokens, then decode s more.
            let mut kv_inc = KvCache::new(&spec, pair.a);
            let pre = model.forward_prefill(&input[..t * d], pair, &cache, &mut kv_inc).unwrap();
            assert_eq!(kv_inc.len(), t);
            let mut steps = Vec::new();
            for i in 0..s {
                let row = &input[(t + i) * d..(t + i + 1) * d];
                steps.push(model.forward_decode(row, pair, &cache, &mut kv_inc).unwrap());
            }
            assert_eq!(kv_inc.len(), t + s);

            // Recompute: one full causal prefill over all t + s tokens.
            let mut kv_full = KvCache::new(&spec, pair.a);
            let full = model.forward_prefill(&input, pair, &cache, &mut kv_full).unwrap();

            let label = format!("{} kv_heads={kv_heads}", pair.label());
            assert_eq!(
                &full[..t * d],
                &pre[..],
                "{label}: prefill rows must be causal-stable under later tokens"
            );
            for (i, step) in steps.iter().enumerate() {
                assert_eq!(
                    &full[(t + i) * d..(t + i + 1) * d],
                    step.as_slice(),
                    "{label}: decode step {i} must equal full recompute bit-for-bit"
                );
            }
            assert_eq!(kv_inc.len(), kv_full.len());
            assert_eq!(kv_inc.bytes(), kv_full.bytes(), "{label}: identical packed KV residency");
        }
    }
}

/// **The zero-repack gate**: a multi-step decode (prefill + several decode
/// steps, MHA and GQA) never takes the K^T extract-and-repack fallback —
/// the resident transposed layout serves every score GEMM by word
/// adoption. The oracle path produces bit-identical codes and is the only
/// thing that moves the counter.
#[test]
fn decode_hot_path_never_repacks() {
    for kv_heads in [4usize, 2, 1] {
        let spec = ModelSpec {
            name: "decode-norepack",
            seq: 16,
            layers: 2,
            d_model: 32,
            d_ff: 48,
            heads: 4,
            gated_ffn: false,
            kv_heads,
        };
        let d = spec.d_model;
        let model = NativeModel::synthesize(spec.clone(), 7);
        let cache = WeightCache::new();
        let pair = PrecisionPair::of_bits(6, 6);
        let mut kv = KvCache::new(&spec, pair.a);
        let mut rng = Rng::new(0x0E9A + kv_heads as u64);
        let input: Vec<f32> = (0..8 * d).map(|_| rng.gauss() as f32 * 0.5).collect();
        model.forward_prefill(&input[..5 * d], pair, &cache, &mut kv).unwrap();
        for i in 5..8 {
            model.forward_decode(&input[i * d..(i + 1) * d], pair, &cache, &mut kv).unwrap();
        }
        assert_eq!(
            kv.repack_count(),
            0,
            "kv_heads={kv_heads}: decode hot path must never repack K^T"
        );
        // The resident page adoption and the repack oracle agree
        // code-for-code (pages are output-column slabs of the dense K^T).
        let hd = spec.head_dim();
        let tokens = kv.len();
        for li in 0..spec.layers {
            for h in 0..kv_heads {
                let slow = kv.k_t_matrix_repacked(li, h, tokens);
                assert_eq!((slow.rows(), slow.cols()), (hd, tokens));
                let dense = slow.codes();
                let mut t0 = 0usize;
                for page in kv.k_t_pages(li, h, tokens) {
                    assert_eq!(page.rows(), hd);
                    let pc = page.codes();
                    for r in 0..hd {
                        assert_eq!(
                            &pc[r * page.cols()..(r + 1) * page.cols()],
                            &dense[r * tokens + t0..r * tokens + t0 + page.cols()],
                            "layer {li} head {h} page at {t0}"
                        );
                    }
                    t0 += page.cols();
                }
                assert_eq!(t0, tokens);
            }
        }
        assert_eq!(kv.repack_count(), (spec.layers * kv_heads) as u64);
    }
}

/// Speculative rollback under the K^T-resident layout: `truncate` then
/// re-append is bit-identical to a fresh cache fed the final sequence —
/// swept across MHA/GQA groupings and word-straddling widths 3, 5, 6, 7
/// (where stale column-tail bits would corrupt neighbors if truncate or
/// the scatter-append mishandled the packed layout).
#[test]
fn kv_rollback_reappend_matches_fresh_cache() {
    let widths = [
        Format::fp(1, 1),               // 3 bits
        Format::Fp(FpFormat::FP5_E2M2), // 5
        Format::Fp(FpFormat::FP6_E3M2), // 6
        Format::fp(3, 3),               // 7
    ];
    for kv_heads in [4usize, 2, 1] {
        let spec = ModelSpec {
            name: "kv-rollback",
            seq: 32,
            layers: 2,
            d_model: 24,
            d_ff: 32,
            heads: 4,
            gated_ffn: false,
            kv_heads,
        };
        let kv_dim = kv_heads * spec.head_dim();
        for fmt in widths {
            let mut rng = Rng::new(0x5EC + kv_heads as u64 + fmt.bits() as u64);
            let row = |rng: &mut Rng| -> Vec<f32> {
                (0..kv_dim).map(|_| rng.gauss() as f32 * 0.5).collect()
            };
            // Final sequence: 6 kept tokens + 5 re-appended after rollback.
            let kept: Vec<(Vec<f32>, Vec<f32>)> =
                (0..6).map(|_| (row(&mut rng), row(&mut rng))).collect();
            let discarded: Vec<(Vec<f32>, Vec<f32>)> =
                (0..4).map(|_| (row(&mut rng), row(&mut rng))).collect();
            let reappended: Vec<(Vec<f32>, Vec<f32>)> =
                (0..5).map(|_| (row(&mut rng), row(&mut rng))).collect();

            let mut kv = KvCache::new(&spec, fmt);
            for (k, v) in kept.iter().chain(discarded.iter()) {
                for li in 0..spec.layers {
                    kv.append_token(li, k, v).unwrap();
                }
                kv.commit(1);
            }
            kv.truncate(kept.len());
            for (k, v) in &reappended {
                for li in 0..spec.layers {
                    kv.append_token(li, k, v).unwrap();
                }
                kv.commit(1);
            }

            let mut fresh = KvCache::new(&spec, fmt);
            for (k, v) in kept.iter().chain(reappended.iter()) {
                for li in 0..spec.layers {
                    fresh.append_token(li, k, v).unwrap();
                }
                fresh.commit(1);
            }

            let tokens = kept.len() + reappended.len();
            assert_eq!(kv.len(), tokens);
            assert_eq!(kv.bytes(), fresh.bytes(), "{fmt} kv_heads={kv_heads}");
            for li in 0..spec.layers {
                for h in 0..kv_heads {
                    let (ka, kb) = (kv.k_t_pages(li, h, tokens), fresh.k_t_pages(li, h, tokens));
                    assert_eq!(ka.len(), kb.len());
                    for (pa, pb) in ka.iter().zip(&kb) {
                        assert_eq!(
                            pa.codes(),
                            pb.codes(),
                            "{fmt} kv_heads={kv_heads} K layer {li} head {h}"
                        );
                    }
                    let (va, vb) = (kv.v_pages(li, h, tokens), fresh.v_pages(li, h, tokens));
                    assert_eq!(va.len(), vb.len());
                    for (pa, pb) in va.iter().zip(&vb) {
                        assert_eq!(
                            pa.codes(),
                            pb.codes(),
                            "{fmt} kv_heads={kv_heads} V layer {li} head {h}"
                        );
                    }
                }
            }
            assert_eq!(kv.repack_count(), 0, "{fmt}: rollback path must stay zero-repack");
        }
    }
}

/// **The value-aware guard's acceptance criterion**: INT8 x INT8 at K=4096
/// with |values| <= 64 sits exactly on the 2^24 boundary — the recorded
/// maxima admit the i32 fast path (the format bound rejects it), and the
/// i32 and f32 paths agree bit-for-bit on the same data at the boundary.
#[test]
fn int8_k4096_value_aware_boundary_bit_exact() {
    let i8f = Format::int(8);
    let (m, k, n) = (2usize, 4096usize, 8usize);
    // Guard arithmetic: 4096 * 64 * 64 == 2^24 exactly.
    assert!(!int_fast_path_exact(i8f, i8f, k), "format bound must reject K=4096");
    assert!(int_fast_path_exact_with(i8f, i8f, k, Some(64), Some(64)));
    assert!(!int_fast_path_exact_with(i8f, i8f, k, Some(64), Some(65)));

    let mut rng = Rng::new(0xB0DE);
    let mut codes = |len: usize, worst: i32| -> Vec<u32> {
        let mut v: Vec<u32> = (0..len)
            .map(|_| {
                let val = rng.below(129) as i32 - 64; // -64 ..= 64
                (val as i8 as u8) as u32
            })
            .collect();
        // Plant the worst-case magnitude so the recorded max is exactly 64
        // (or the adversarial 65) and the boundary is actually exercised.
        v[0] = (worst as i8 as u8) as u32;
        v
    };
    let a_codes = codes(m * k, -64);
    let w_codes = codes(k * n, 64);
    let want = gemm_ref(&a_codes, i8f, &w_codes, i8f, m, k, n);

    // Packed with recorded maxima: the kernel takes the i32 fast path.
    let a = PackedMatrix::from_codes(&a_codes, m, k, i8f);
    let w = PackedMatrix::from_codes(&w_codes, k, n, i8f);
    assert_eq!((a.max_abs(), w.max_abs()), (Some(64), Some(64)));
    assert!(int_fast_path_exact_with(i8f, i8f, k, a.max_abs(), w.max_abs()));
    assert_eq!(gemm_default(&a, &w), want, "i32 fast path at the boundary");

    // Same words adopted without maxima: the guard falls back to the
    // format bound, the f32 path runs — and must agree bit-for-bit.
    let a_blind = PackedMatrix::from_tensor(PackedTensor::from_codes(&a_codes, i8f), m, k);
    let w_blind = PackedMatrix::from_tensor(PackedTensor::from_codes(&w_codes, i8f), k, n);
    assert_eq!((a_blind.max_abs(), w_blind.max_abs()), (None, None));
    assert_eq!(gemm_default(&a_blind, &w_blind), want, "f32 fallback must agree at the boundary");

    // The worst case actually accumulates to 2^24: all-64 x all-64 rows.
    let a_max = PackedMatrix::from_codes(&vec![64u32; k], 1, k, i8f);
    let w_max = PackedMatrix::from_codes(&vec![64u32; k * 2], k, 2, i8f);
    let got = gemm_default(&a_max, &w_max);
    assert_eq!(got, vec![(1u32 << 24) as f32; 2], "boundary sum is exact in f32");

    // One value beyond the bound (|v| = 65): guard rejects, fallback still
    // matches the reference.
    let a65_codes = codes(m * k, 65);
    let a65 = PackedMatrix::from_codes(&a65_codes, m, k, i8f);
    assert_eq!(a65.max_abs(), Some(65));
    assert!(!int_fast_path_exact_with(i8f, i8f, k, a65.max_abs(), w.max_abs()));
    assert_eq!(
        gemm_default(&a65, &w),
        gemm_ref(&a65_codes, i8f, &w_codes, i8f, m, k, n),
        "out-of-bound data must fall back exactly"
    );
}

/// The M=1 GEMV dispatch is bit-identical to the tiled kernel on KV-cache
/// operands too (strided resident K^T and adopted V), across FP and INT
/// session formats.
#[test]
fn gemv_matches_tiled_on_kv_operands() {
    let spec = ModelSpec {
        name: "gemv-kv",
        seq: 64,
        layers: 1,
        d_model: 16,
        d_ff: 16,
        heads: 1,
        gated_ffn: false,
        kv_heads: 1,
    };
    let hd = spec.head_dim();
    for fmt in [Format::Fp(FpFormat::FP6_E3M2), Format::int(8)] {
        let mut rng = Rng::new(0x6E3 + fmt.bits() as u64);
        let mut kv = KvCache::new(&spec, fmt);
        let tokens = 40usize; // not a power of two, straddles words
        for _ in 0..tokens {
            let k_row: Vec<f32> = (0..hd).map(|_| rng.gauss() as f32 * 0.5).collect();
            let v_row: Vec<f32> = (0..hd).map(|_| rng.gauss() as f32 * 0.5).collect();
            kv.append_token(0, &k_row, &v_row).unwrap();
            kv.commit(1);
        }
        // 40 tokens < one page: the page runs are single matrices.
        let kp = kv.k_t_pages(0, 0, tokens).remove(0);
        let vp = kv.v_pages(0, 0, tokens).remove(0);
        let q: Vec<f32> = (0..hd).map(|_| rng.gauss() as f32 * 0.5).collect();
        let qp = PackedMatrix::from_f32(&q, 1, hd, fmt);
        let p: Vec<f32> = (0..tokens).map(|_| rng.gauss() as f32 * 0.1).collect();
        let pp = PackedMatrix::from_f32(&p, 1, tokens, fmt);
        let cfg = GemmConfig::default();
        assert_eq!(gemm(&qp, &kp, &cfg), gemm_tiled(&qp, &kp, &cfg), "{fmt} score GEMV");
        assert_eq!(gemm(&pp, &vp, &cfg), gemm_tiled(&pp, &vp, &cfg), "{fmt} context GEMV");
        // And against the repacked-oracle operand (dense layout).
        let kp_dense = kv.k_t_matrix_repacked(0, 0, tokens);
        assert_eq!(gemm(&qp, &kp, &cfg), gemm(&qp, &kp_dense, &cfg), "{fmt} strided == dense");
    }
}

/// Chunked prefill composes: prefilling in two chunks equals one prefill.
#[test]
fn chunked_prefill_matches_single_prefill() {
    let spec = ModelSpec::tiny();
    let d = spec.d_model;
    let pair = PrecisionPair::of_bits(5, 6);
    let model = NativeModel::synthesize(spec.clone(), 9);
    let cache = WeightCache::new();
    let mut rng = Rng::new(21);
    let input: Vec<f32> = (0..8 * d).map(|_| rng.gauss() as f32 * 0.5).collect();

    let mut kv_a = KvCache::new(&spec, pair.a);
    let full = model.forward_prefill(&input, pair, &cache, &mut kv_a).unwrap();

    let mut kv_b = KvCache::new(&spec, pair.a);
    let first = model.forward_prefill(&input[..5 * d], pair, &cache, &mut kv_b).unwrap();
    let second = model.forward_prefill(&input[5 * d..], pair, &cache, &mut kv_b).unwrap();
    assert_eq!(&full[..5 * d], &first[..]);
    assert_eq!(&full[5 * d..], &second[..]);
    assert_eq!(kv_a.bytes(), kv_b.bytes());
}

/// End-to-end token streams through the server: interleaved sessions at
/// mixed precision, each driven by per-request completions, produce
/// **exactly** the outputs of driving the same model offline — serving
/// (batching, continuous admission, shared weight cache) is bit-transparent.
#[test]
fn served_token_streams_match_offline_decode() {
    let spec = ModelSpec {
        name: "tiny-decode-e2e",
        seq: 16,
        layers: 1,
        d_model: 32,
        d_ff: 64,
        heads: 4,
        gated_ffn: false,
        kv_heads: 2,
    };
    let d = spec.d_model;
    let seed = 99u64;
    let pairs =
        [PrecisionPair::of_bits(6, 6), PrecisionPair::new(Format::int(4), Format::default_fp(16))];
    let n_sessions = 4usize;
    let prefill_len = 4usize;
    let steps = 3usize;

    // Deterministic per-session inputs, shared by oracle and server.
    let mut rng = Rng::new(7);
    let mut prefills = Vec::new();
    let mut tokens: Vec<Vec<Vec<f32>>> = Vec::new();
    for _ in 0..n_sessions {
        prefills
            .push((0..prefill_len * d).map(|_| rng.gauss() as f32 * 0.5).collect::<Vec<f32>>());
        tokens.push(
            (0..steps)
                .map(|_| (0..d).map(|_| rng.gauss() as f32 * 0.5).collect())
                .collect(),
        );
    }

    // Offline oracle: same weights, same inputs, direct model calls.
    let model = NativeModel::synthesize(spec.clone(), seed);
    let cache = WeightCache::new();
    let mut expected: Vec<Vec<Vec<f32>>> = Vec::new(); // [session][step][row]
    for si in 0..n_sessions {
        let pair = pairs[si % pairs.len()];
        let mut kv = KvCache::new(&spec, pair.a);
        let mut outs =
            vec![model.forward_prefill(&prefills[si], pair, &cache, &mut kv).unwrap()];
        for tok in &tokens[si] {
            outs.push(model.forward_decode(tok, pair, &cache, &mut kv).unwrap());
        }
        expected.push(outs);
    }

    // Served: interleaved sessions, one outstanding request per stream,
    // driven through the coordinator's StreamDriver.
    let executor = NativeExecutor::new().with_model(spec.clone(), seed);
    let cfg = ServerConfig {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1), max_streak: 4 },
        sim_config: flexibit::sim::mobile_a(),
        sim_model: spec.clone(),
        recorder: flexibit::obs::Recorder::disabled(),
        drift: None,
        resilience: Resilience::default(),
        kv_pool: None,
    };
    let server = Server::start(cfg, Box::new(executor));
    let session_specs = (0..n_sessions)
        .map(|si| {
            (si as u64 + 1, pairs[si % pairs.len()], prefills[si].clone(), vec![prefill_len, d])
        })
        .collect();
    let mut driver = StreamDriver::start(&server, spec.name, session_specs);
    let mut got: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n_sessions];
    let finished = driver.run(
        &server,
        Instant::now() + Duration::from_secs(60),
        |si, step, result| {
            got[si].push(result.expect("no request may fail"));
            if step < steps {
                Some(tokens[si][step].clone())
            } else {
                None
            }
        },
    );
    assert!(finished, "token streams timed out");
    let m = server.shutdown();
    assert_eq!(m.sessions_started, n_sessions as u64);
    assert_eq!(m.decode_steps, (n_sessions * steps) as u64);
    assert_eq!(m.requests_failed(), 0);
    for (si, outs) in got.iter().enumerate() {
        assert_eq!(outs.len(), steps + 1);
        for (k, out) in outs.iter().enumerate() {
            assert_eq!(
                out,
                &expected[si][k],
                "session {si} step {k}: served output must equal offline decode bit-for-bit"
            );
        }
    }
}
