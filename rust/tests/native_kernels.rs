//! Native bit-packed GEMM engine: golden-equivalence and serving
//! integration tests.
//!
//! The contract under test: for **every** supported precision pair —
//! including non-power-of-two widths — the tiled/threaded kernel in
//! `flexibit::kernels` is bit-identical to `arith::gemm_ref`, and the
//! reference itself tracks the exact integer golden model (`dot_exact`)
//! within f32 accumulation error. Plus: end-to-end serving through
//! `NativeExecutor` with zero artifacts on disk.

use flexibit::arith::{decode, dot_exact, gemm_ref, Format, FpFormat};
use flexibit::coordinator::{BatchPolicy, Request, Server, ServerConfig};
use flexibit::kernels::{gemm, gemm_default, GemmConfig, NativeExecutor, PackedMatrix};
use flexibit::util::{property, Rng};
use flexibit::workload::{ModelSpec, PrecisionPair};
use std::time::{Duration, Instant};

/// The evaluation formats: FP4/FP5/FP6 (both variants)/FP8 (E4M3 + E5M2),
/// INT4/INT8 — every cross of these is a supported precision pair.
fn formats() -> Vec<Format> {
    vec![
        Format::Fp(FpFormat::FP4_E2M1),
        Format::Fp(FpFormat::FP5_E2M2),
        Format::Fp(FpFormat::FP6_E3M2),
        Format::Fp(FpFormat::FP6_E2M3),
        Format::Fp(FpFormat::FP8_E4M3),
        Format::Fp(FpFormat::FP8_E5M2),
        Format::int(4),
        Format::int(8),
    ]
}

fn assert_kernel_matches_golden(
    rng: &mut Rng,
    a_fmt: Format,
    w_fmt: Format,
    m: usize,
    k: usize,
    n: usize,
    cfg: &GemmConfig,
) {
    let a_codes = rng.codes(m * k, a_fmt.bits());
    let w_codes = rng.codes(k * n, w_fmt.bits());
    let a = PackedMatrix::from_codes(&a_codes, m, k, a_fmt);
    let w = PackedMatrix::from_codes(&w_codes, k, n, w_fmt);
    let got = gemm(&a, &w, cfg);
    let want = gemm_ref(&a_codes, a_fmt, &w_codes, w_fmt, m, k, n);
    assert_eq!(got, want, "{a_fmt}x{w_fmt} {m}x{k}x{n} (cfg {cfg:?})");
}

/// Every format cross, random tensors: kernel == golden reference, exactly.
#[test]
fn all_precision_crosses_match_golden_exactly() {
    let mut rng = Rng::new(0xC0FFEE);
    let cfg = GemmConfig::default();
    for &a_fmt in &formats() {
        for &w_fmt in &formats() {
            let (m, k, n) = (5, 33, 9); // off-tile on every axis
            assert_kernel_matches_golden(&mut rng, a_fmt, w_fmt, m, k, n, &cfg);
        }
    }
}

/// Property sweep: random formats (arbitrary e/m, any width 3..=16 plus
/// INTs), random non-multiple-of-tile shapes, random tile configs.
#[test]
fn randomized_formats_shapes_and_tilings() {
    property(0xF1E8, 60, |rng| {
        let pick = |rng: &mut Rng| -> Format {
            if rng.below(4) == 0 {
                Format::int(2 + rng.below(9) as u8)
            } else {
                Format::fp(1 + rng.below(5) as u8, rng.below(8) as u8)
            }
        };
        let a_fmt = pick(rng);
        let w_fmt = pick(rng);
        let m = 1 + rng.below(24) as usize;
        let k = 1 + rng.below(90) as usize;
        let n = 1 + rng.below(70) as usize;
        let cfg = GemmConfig {
            kc: 1 + rng.below(80) as usize,
            nc: 1 + rng.below(80) as usize,
            threads: 1 + rng.below(4) as usize,
        };
        let mut case_rng = Rng::new(rng.next_u64());
        assert_kernel_matches_golden(&mut case_rng, a_fmt, w_fmt, m, k, n, &cfg);
    });
}

/// Edge shapes: single row/column/element, K=1, tall-skinny, wide-flat.
#[test]
fn edge_case_shapes() {
    let mut rng = Rng::new(0xED6E);
    let fp6 = Format::Fp(FpFormat::FP6_E3M2);
    let fp5 = Format::Fp(FpFormat::FP5_E2M2);
    let cfg = GemmConfig::default();
    for &(m, k, n) in
        &[(1, 1, 1), (1, 1, 129), (129, 1, 1), (1, 257, 1), (3, 64, 64), (64, 65, 63), (2, 7, 2)]
    {
        assert_kernel_matches_golden(&mut rng, fp6, fp5, m, k, n, &cfg);
    }
}

/// The f32 reference itself must track the exact fixed-point golden model.
#[test]
fn reference_tracks_exact_golden_model() {
    let mut rng = Rng::new(0x60);
    for &fmt in &[Format::Fp(FpFormat::FP6_E3M2), Format::int(8)] {
        let (m, k, n) = (3usize, 16usize, 4usize);
        let a = rng.codes(m * k, fmt.bits());
        let w = rng.codes(k * n, fmt.bits());
        let c = gemm_ref(&a, fmt, &w, fmt, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let a_row: Vec<u32> = (0..k).map(|kk| a[i * k + kk]).collect();
                let w_col: Vec<u32> = (0..k).map(|kk| w[kk * n + j]).collect();
                let exact = dot_exact(&a_row, fmt, &w_col, fmt);
                let scale: f64 = a_row
                    .iter()
                    .zip(&w_col)
                    .map(|(&ab, &wb)| (decode(ab, fmt) * decode(wb, fmt)).abs())
                    .sum::<f64>()
                    .max(1.0);
                let tol = scale * k as f64 * f32::EPSILON as f64;
                assert!(
                    (c[i * n + j] as f64 - exact).abs() <= tol,
                    "[{i},{j}] {fmt}: f32 {} vs exact {exact}",
                    c[i * n + j]
                );
            }
        }
    }
}

/// Quantize-then-pack path: f32 inputs end up identical to encode+pack.
#[test]
fn quantized_activations_roundtrip_through_kernel() {
    let mut rng = Rng::new(0xAC);
    let a_fmt = Format::Fp(FpFormat::FP8_E4M3);
    let w_fmt = Format::Fp(FpFormat::FP6_E3M2);
    let (m, k, n) = (4usize, 20usize, 6usize);
    let a_vals: Vec<f32> = (0..m * k).map(|_| rng.gauss() as f32 * 0.5).collect();
    let w_vals: Vec<f32> = (0..k * n).map(|_| rng.gauss() as f32 * 0.3).collect();
    let a = PackedMatrix::from_f32(&a_vals, m, k, a_fmt);
    let w = PackedMatrix::from_f32(&w_vals, k, n, w_fmt);
    let got = gemm_default(&a, &w);
    let want = gemm_ref(&a.codes(), a_fmt, &w.codes(), w_fmt, m, k, n);
    assert_eq!(got, want);
    // And the quantization itself is the arith encode (spot check).
    assert_eq!(a.get(0, 0), {
        let q = flexibit::arith::encode(a_vals[0] as f64, a_fmt);
        decode(q, a_fmt)
    });
}

/// End-to-end: the server drains a mixed-precision stream through the
/// native executor — including FP6xFP6 — with zero artifacts on disk, and
/// the weight cache packs once per (model, weight format).
#[test]
fn server_serves_mixed_precision_natively() {
    let spec = ModelSpec {
        name: "tiny-native-e2e",
        seq: 8,
        layers: 1,
        d_model: 32,
        d_ff: 64,
        heads: 2,
        gated_ffn: false,
        kv_heads: 2,
    };
    let executor = NativeExecutor::new().with_model(spec.clone(), 99);
    let cfg = ServerConfig {
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            max_streak: 4,
        },
        sim_config: flexibit::sim::mobile_a(),
        sim_model: spec.clone(),
    };
    let server = Server::start(cfg, Box::new(executor));
    let pairs = [
        PrecisionPair::of_bits(6, 6),
        PrecisionPair::of_bits(5, 8),
        PrecisionPair::new(Format::int(4), Format::default_fp(16)),
    ];
    let n_requests = 12u64;
    let mut rng = Rng::new(5);
    for i in 0..n_requests {
        let input: Vec<f32> =
            (0..spec.seq * spec.d_model).map(|_| rng.gauss() as f32 * 0.5).collect();
        server.submit(Request {
            id: i,
            model: spec.name.to_string(),
            pair: pairs[(i % 3) as usize],
            input,
            dims: vec![spec.seq, spec.d_model],
            arrived: Instant::now(),
        });
    }
    server.await_completed(n_requests, Duration::from_secs(30));
    let m = server.shutdown();
    assert_eq!(m.requests_completed, n_requests, "all native requests complete");
    assert!(m.batches_executed >= 3, "one batch per precision at least");
    assert!(m.host_exec_s > 0.0, "native execution accrues host time");
    assert!(m.sim_accel_s > 0.0 && m.sim_energy_j > 0.0, "co-simulation still runs");
}

/// Unknown model → the executor reports an error (and the server survives).
#[test]
fn executor_rejects_unknown_model() {
    use flexibit::coordinator::{Batch, Executor};
    let mut ex = NativeExecutor::new().with_model(ModelSpec::tiny(), 1);
    let batch = Batch {
        model: "unregistered".to_string(),
        pair: PrecisionPair::of_bits(6, 6),
        requests: vec![],
    };
    assert!(ex.execute(&batch).is_err());
    assert_eq!(ex.name(), "native");
}
