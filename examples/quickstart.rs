//! Quickstart: the 60-second tour of the library.
//!
//! 1. Quantize a small tensor to FP6 and bit-pack it.
//! 2. Multiply arbitrary-format operands through the bit-exact FlexiBit PE
//!    and check against the golden model.
//! 3. Simulate GPT-3 prefill at FP6 on a cloud-scale FlexiBit vs a Tensor
//!    Core-like baseline — the paper's headline comparison.
//!
//! Run: `cargo run --release --example quickstart`

use flexibit::arith::{decode, dot_exact, Format, PackedTensor};
use flexibit::baselines::{Accel, FlexiBitAccel, TensorCoreAccel};
use flexibit::pe::{Pe, PeConfig};
use flexibit::report::{fmt_j, fmt_s};
use flexibit::sim::{cloud_b, simulate_model};
use flexibit::workload::{gpt3, PrecisionPair};

fn main() {
    // --- 1. Arbitrary-precision quantization + bit packing ---------------
    let fp6 = Format::parse("e3m2").unwrap();
    let values = [0.7f64, -1.3, 2.25, 0.11, -6.0, 3.3, 0.0, 9.9];
    let packed = PackedTensor::from_f64(&values, fp6);
    println!("FP6 (e3m2) quantization:");
    for (v, q) in values.iter().zip(packed.to_f64()) {
        println!("  {v:>6} -> {q:>6}");
    }
    println!(
        "packed: {} bytes ({} values x 6 bits); byte-padded would be {} bytes\n",
        packed.bytes(),
        packed.len,
        packed.padded_bytes()
    );

    // --- 2. Bit-exact PE multiplication -----------------------------------
    let fp5 = Format::parse("e2m2").unwrap();
    let mut pe = Pe::new(PeConfig::default());
    let acts = [0b110101u32, 0b001011, 0b011111, 0b100001]; // 4 x FP6
    let wgts = [0b10101u32, 0b01010, 0b11111, 0b00001]; // 4 x FP5
    let win = pe.multiply_window(&acts, fp6, &wgts, fp5);
    println!(
        "PE window: {} simultaneous FP6xFP5 products in one cycle (bit-parallel):",
        win.products.len()
    );
    for (oid, p) in win.products.iter().take(4).enumerate() {
        let (wi, ai) = (oid / win.n_acts, oid % win.n_acts);
        println!(
            "  a={:.3} x w={:.3} = {:.4}",
            decode(acts[ai], fp6),
            decode(wgts[wi], fp5),
            p.value()
        );
    }
    // Dot product through the full accumulate path, checked vs golden.
    let d = pe.dot(&acts, fp6, &wgts, fp5);
    assert_eq!(d, dot_exact(&acts, fp6, &wgts, fp5));
    println!("dot product via ENU/CST/ANU path: {d} (matches golden model)\n");

    // --- 3. The headline simulation ---------------------------------------
    let pair = PrecisionPair::of_bits(6, 6);
    let cfg = cloud_b();
    let model = gpt3();
    let fb = simulate_model(&FlexiBitAccel::new(), &cfg, &model, pair);
    let tc = simulate_model(&TensorCoreAccel::new(), &cfg, &model, pair);
    println!("GPT-3 prefill (seq 2048) at [W6,A6] on {}:", cfg.name);
    println!("  FlexiBit:   latency {}  energy {}", fmt_s(fb.seconds), fmt_j(fb.energy_j));
    println!("  TensorCore: latency {}  energy {}", fmt_s(tc.seconds), fmt_j(tc.energy_j));
    println!(
        "  -> {:.0}% less latency, {:.0}% less energy (paper: 59% / 66% avg at FP6)",
        100.0 * (1.0 - fb.seconds / tc.seconds),
        100.0 * (1.0 - fb.energy_j / tc.energy_j)
    );
}
