//! End-to-end driver: serve a real (tiny) FlexiBit-quantized transformer
//! through all three layers.
//!
//! * L1/L2 (build time): `make artifacts` quantized the block's weights to
//!   FP4/5/6/8, bit-packed them, and AOT-lowered the Pallas-kernel forward
//!   to HLO text.
//! * L3 (this binary): loads the artifacts on the PJRT CPU client, checks
//!   numerics against the Python-side golden I/O pair, then runs the
//!   serving coordinator — request queue, precision-aware dynamic batcher,
//!   PJRT executor — over a synthetic mixed-precision request stream and
//!   reports latency/throughput plus the co-simulated FlexiBit accelerator
//!   estimates.
//!
//! Requires `make artifacts` first. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_transformer`

use flexibit::coordinator::{BatchPolicy, Request, Server, ServerConfig};
use flexibit::runtime::{artifacts_dir, load_block_weights, InputBuf, Runtime};
use flexibit::workload::{ModelSpec, PrecisionPair};
use std::cell::OnceCell;
use std::time::Instant;

/// Minimal JSON number-array extraction (no serde in the offline build):
/// pulls the flat numeric array following `"<key>": [`.
fn json_f32_array(text: &str, key: &str) -> Vec<f32> {
    let pat = format!("\"{key}\":");
    let start = text.find(&pat).expect("key present") + pat.len();
    let rest = &text[start..];
    let lb = rest.find('[').unwrap();
    let rb = rest[lb..].find(']').unwrap() + lb;
    rest[lb + 1..rb]
        .split(',')
        .filter_map(|s| s.trim().parse::<f32>().ok())
        .collect()
}

fn tiny_model_spec() -> ModelSpec {
    // Matches aot.py's BlockConfig defaults (seq 32, d_model 128, d_ff 256).
    ModelSpec {
        name: "tiny-block",
        seq: 32,
        layers: 1,
        d_model: 128,
        d_ff: 256,
        heads: 4,
        gated_ffn: false,
        kv_heads: 4,
    }
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not found in {} — run `make artifacts` first", dir.display());
        std::process::exit(1);
    }

    // --- 1. Load + verify numerics against the Python golden output ------
    let mut rt = Runtime::new()?;
    let loaded = rt.load_artifacts_dir(&dir)?;
    println!("PJRT platform: {}; loaded artifacts: {loaded:?}", rt.platform());

    let mut max_err_all = 0f32;
    for bits in [4u32, 5, 6, 8] {
        let name = format!("block_w{bits}");
        let io = std::fs::read_to_string(dir.join(format!("{name}.io.json")))?;
        let input = json_f32_array(&io, "input");
        let expect = json_f32_array(&io, "output");
        let weights = load_block_weights(&dir.join(format!("{name}.weights.json")))?;
        let mut inputs = vec![InputBuf::F32(&input, vec![32, 128])];
        for (words, shape) in &weights {
            inputs.push(InputBuf::U32(words, shape.clone()));
        }
        let out = rt.execute_mixed(&name, &inputs)?;
        let got = &out[0];
        assert_eq!(got.len(), expect.len());
        let max_err = got
            .iter()
            .zip(&expect)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        max_err_all = max_err_all.max(max_err);
        println!("  {name}: PJRT output vs Python eager max |err| = {max_err:.2e}");
        assert!(max_err < 1e-4, "numerics mismatch on {name}");
    }
    println!("numerics verified across all weight precisions (max err {max_err_all:.2e})\n");

    // --- 2. Serve a mixed-precision request stream ------------------------
    let cfg = ServerConfig {
        policy: BatchPolicy::default(),
        sim_config: flexibit::sim::mobile_a(),
        sim_model: tiny_model_spec(),
    };
    // PJRT client is not Send: build it lazily inside the worker thread.
    let adir = dir.clone();
    let executor = Box::new(move |batch: &flexibit::coordinator::Batch| {
        type Cache = (Runtime, std::collections::HashMap<u32, Vec<(Vec<u32>, Vec<usize>)>>);
        thread_local! {
            static RT: OnceCell<Cache> = const { OnceCell::new() };
        }
        RT.with(|cell| {
            let (rt, weights) = match cell.get() {
                Some(c) => c,
                None => {
                    let mut r = Runtime::new().expect("pjrt client");
                    r.load_artifacts_dir(&adir).expect("artifacts");
                    let mut w = std::collections::HashMap::new();
                    for bits in [4u32, 5, 6, 8] {
                        let path = adir.join(format!("block_w{bits}.weights.json"));
                        w.insert(bits, load_block_weights(&path).expect("weights"));
                    }
                    let _ = cell.set((r, w));
                    cell.get().unwrap()
                }
            };
            let t0 = Instant::now();
            let bits = batch.pair.w.bits();
            let model = format!("block_w{bits}");
            let wts = &weights[&bits];
            for req in &batch.requests {
                let mut inputs = vec![InputBuf::F32(&req.input, req.dims.clone())];
                for (words, shape) in wts {
                    inputs.push(InputBuf::U32(words, shape.clone()));
                }
                rt.execute_mixed(&model, &inputs)?;
            }
            Ok(t0.elapsed().as_secs_f64())
        })
    });

    let server = Server::start(cfg, executor);
    let n_requests = 64;
    let t0 = Instant::now();
    let mut rng = flexibit::util::Rng::new(7);
    for i in 0..n_requests {
        let bits = [4u32, 5, 6, 8][(i % 4) as usize];
        let input: Vec<f32> = (0..32 * 128).map(|_| rng.gauss() as f32 * 0.5).collect();
        server.submit(Request {
            id: i,
            model: "tiny-block".into(),
            pair: PrecisionPair::of_bits(bits, 16),
            input,
            dims: vec![32, 128],
            arrived: Instant::now(),
        });
    }
    // Drain.
    let deadline = Instant::now() + std::time::Duration::from_secs(120);
    loop {
        let m = server.metrics();
        if m.requests_completed >= n_requests || Instant::now() > deadline {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();

    println!("== serving results ({n_requests} mixed-precision requests) ==");
    println!("  completed:        {}", m.requests_completed);
    println!("  batches:          {} (mean size {:.1})", m.batches_executed, m.mean_batch_size());
    println!("  precision switches: {}", m.reconfigurations);
    println!("  wall time:        {wall:.2}s  ({:.1} req/s)", m.throughput_rps(wall));
    println!("  mean latency:     {:.1} ms (max {:.1} ms)", m.mean_latency_s() * 1e3, m.latency_max_s * 1e3);
    println!("  host PJRT time:   {:.2}s", m.host_exec_s);
    println!("== co-simulated FlexiBit accelerator (Mobile-A) ==");
    println!("  simulated latency: {:.3} ms/batch avg", m.sim_accel_s / m.batches_executed.max(1) as f64 * 1e3);
    println!("  simulated energy:  {:.3} mJ total", m.sim_energy_j * 1e3);
    assert_eq!(m.requests_completed, n_requests, "all requests must complete");
    println!("\nserve_transformer OK — three layers composed end-to-end");
    Ok(())
}
