//! End-to-end driver: serve a (tiny) FlexiBit-quantized transformer through
//! the native bit-packed GEMM engine — no Python, no PJRT, no artifacts.
//!
//! * Numerics first: for every precision pair in the request mix, the native
//!   kernel is checked **bit-for-bit** against the `arith::golden` reference
//!   GEMM on random packed tensors (the software analog of the paper's RTL
//!   verification, at GEMM granularity).
//! * Then serving: the coordinator — request queue, precision-aware dynamic
//!   batcher, `NativeExecutor` — drains a synthetic mixed-precision request
//!   stream (including non-power-of-two FP6xFP6 and FP5) and reports
//!   latency/throughput plus the co-simulated FlexiBit accelerator
//!   estimates. Packed weights are cached per (model, weight format), so
//!   each precision configuration quantizes exactly once.
//! * Finally decode: a pool of token-stream sessions — one causal prefill
//!   opening a bit-packed KV cache, then single-token decode steps driven
//!   by per-request [`flexibit::coordinator::Completion`] results — the
//!   autoregressive regime arbitrary-precision serving actually runs in.
//!
//! The AOT/PJRT path this example used to exercise remains available behind
//! `--features pjrt` (see `rust/src/runtime/`); it is no longer required.
//!
//! Run: `cargo run --release --example serve_transformer`

use flexibit::arith::{gemm_ref, Format};
use flexibit::coordinator::{BatchPolicy, Request, Server, ServerConfig, StreamDriver};
use flexibit::kernels::{gemm_default, NativeExecutor, PackedMatrix};
use flexibit::util::Rng;
use flexibit::workload::{ModelSpec, PrecisionPair};
use std::time::{Duration, Instant};

/// The request mix: FP6xFP6 (the paper's headline non-power-of-two point),
/// FP5, FP4xFP8, and a GPTQ-style INT4 x FP16.
fn precision_mix() -> Vec<PrecisionPair> {
    vec![
        PrecisionPair::of_bits(6, 6),
        PrecisionPair::of_bits(5, 6),
        PrecisionPair::of_bits(4, 8),
        PrecisionPair::new(Format::int(4), Format::default_fp(16)),
    ]
}

fn main() {
    // --- 1. Golden equivalence of the native kernel ----------------------
    let mut rng = Rng::new(7);
    let (m, k, n) = (16usize, 96usize, 48usize);
    for pair in precision_mix() {
        let a_codes = rng.codes(m * k, pair.a.bits());
        let w_codes = rng.codes(k * n, pair.w.bits());
        let a = PackedMatrix::from_codes(&a_codes, m, k, pair.a);
        let w = PackedMatrix::from_codes(&w_codes, k, n, pair.w);
        let got = gemm_default(&a, &w);
        let want = gemm_ref(&a_codes, pair.a, &w_codes, pair.w, m, k, n);
        assert_eq!(got, want, "native kernel diverged from golden at {}", pair.label());
        println!(
            "  {} native GEMM {}x{}x{} == golden reference (bit-exact); packed W {}B vs padded {}B",
            pair.label(),
            m,
            k,
            n,
            w.bytes(),
            w.padded_bytes()
        );
    }
    println!("numerics verified across all served precision pairs\n");

    // --- 2. Serve a mixed-precision request stream ------------------------
    let spec = ModelSpec::tiny();
    let executor = NativeExecutor::new().with_model(spec.clone(), 0xF1E81B);
    let cfg = ServerConfig {
        policy: BatchPolicy::default(),
        sim_config: flexibit::sim::mobile_a(),
        sim_model: spec.clone(),
        recorder: flexibit::obs::Recorder::disabled(),
        drift: None,
    };
    let server = Server::start(cfg, Box::new(executor));

    let n_requests = 64u64;
    let pairs = precision_mix();
    let t0 = Instant::now();
    for i in 0..n_requests {
        let pair = pairs[(i % pairs.len() as u64) as usize];
        let input: Vec<f32> =
            (0..spec.seq * spec.d_model).map(|_| rng.gauss() as f32 * 0.5).collect();
        server.submit(Request::new(i, spec.name, pair, input, vec![spec.seq, spec.d_model]));
    }
    // Drain.
    let drained = server.await_completed(n_requests, Duration::from_secs(120));
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    assert!(drained, "drain timed out: {}/{n_requests} completed", m.requests_completed);

    println!("== native serving results ({n_requests} mixed-precision requests) ==");
    println!("  completed:          {}", m.requests_completed);
    println!(
        "  batches:            {} (mean size {:.1})",
        m.batches_executed,
        m.mean_batch_size()
    );
    println!("  precision switches: {}", m.reconfigurations);
    println!("  wall time:          {wall:.2}s  ({:.1} req/s)", m.throughput_rps(wall));
    println!(
        "  latency:            mean {:.1} ms, p50 {:.1}, p95 {:.1}, p99 {:.1}, max {:.1} ms",
        m.mean_latency_s() * 1e3,
        m.latency_p(0.50) * 1e3,
        m.latency_p(0.95) * 1e3,
        m.latency_p(0.99) * 1e3,
        m.latency_max_s() * 1e3
    );
    println!("  host exec time:     {:.2}s", m.host_exec_s);
    println!("== co-simulated FlexiBit accelerator (Mobile-A) ==");
    println!(
        "  simulated latency:  {:.3} ms/batch avg",
        m.sim_accel_s / m.batches_executed.max(1) as f64 * 1e3
    );
    println!("  simulated energy:   {:.3} mJ total", m.sim_energy_j * 1e3);
    assert_eq!(m.requests_completed, n_requests, "all requests must complete");

    // --- 3. Token-stream sessions: prefill + autoregressive decode --------
    // Each session opens with a causal prefill (populating a KV cache held
    // bit-packed at the session's activation precision), then streams
    // single-token decode steps. Every request carries a Completion slot,
    // so the driver learns each step's own result and keeps all streams one
    // request deep — interleaved streams are exactly what the batcher's
    // continuous admission groups into decode batches.
    let executor = NativeExecutor::new().with_model(spec.clone(), 0xF1E81B);
    let cfg = ServerConfig {
        policy: BatchPolicy::default(),
        sim_config: flexibit::sim::mobile_a(),
        sim_model: spec.clone(),
        recorder: flexibit::obs::Recorder::disabled(),
        drift: None,
    };
    let server = Server::start(cfg, Box::new(executor));

    let n_sessions = 8u64;
    let steps = 6usize;
    let d = spec.d_model;
    let prefill_len = 16usize;
    let t0 = Instant::now();
    let session_specs = (0..n_sessions)
        .map(|i| {
            let input: Vec<f32> = (0..prefill_len * d).map(|_| rng.gauss() as f32 * 0.5).collect();
            (i + 1, pairs[(i % pairs.len() as u64) as usize], input, vec![prefill_len, d])
        })
        .collect();
    let mut driver = StreamDriver::start(&server, spec.name, session_specs);
    let mut failed = vec![false; n_sessions as usize];
    let finished = driver.run(
        &server,
        Instant::now() + Duration::from_secs(120),
        |i, step, result| match result {
            Err(e) => {
                eprintln!("  session {} failed: {e}", i + 1);
                failed[i] = true;
                None
            }
            Ok(out) => {
                // Every step returns the new token's hidden state row
                // (prefill returns all rows).
                assert!(out.len() % d == 0 && !out.is_empty());
                if step < steps {
                    Some((0..d).map(|_| rng.gauss() as f32 * 0.5).collect())
                } else {
                    None
                }
            }
        },
    );
    assert!(finished, "token streams timed out");
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    println!("== token-stream sessions ({n_sessions} sessions x {steps} decode steps) ==");
    println!("  sessions started:   {}", m.sessions_started);
    println!("  decode steps:       {}", m.decode_steps);
    println!(
        "  decode batching:    {} batches (mean size {:.1})",
        m.batches_executed,
        m.mean_batch_size()
    );
    println!(
        "  decode latency:     p50 {:.2} ms, p99 {:.2} ms",
        m.decode_latency.quantile(0.50) * 1e3,
        m.decode_latency.quantile(0.99) * 1e3
    );
    println!(
        "  wall time:          {wall:.2}s  ({:.1} steps/s)",
        m.decode_steps as f64 / wall.max(1e-9)
    );
    assert!(failed.iter().all(|f| !f), "no session may fail");
    assert_eq!(m.sessions_started, n_sessions);
    assert_eq!(m.decode_steps, n_sessions * steps as u64);

    println!("\nserve_transformer OK — any-precision serving with zero PJRT artifacts");
}
