//! End-to-end driver: serve a (tiny) FlexiBit-quantized transformer through
//! the native bit-packed GEMM engine — no Python, no PJRT, no artifacts.
//!
//! * Numerics first: for every precision pair in the request mix, the native
//!   kernel is checked **bit-for-bit** against the `arith::golden` reference
//!   GEMM on random packed tensors (the software analog of the paper's RTL
//!   verification, at GEMM granularity).
//! * Then serving: the coordinator — request queue, precision-aware dynamic
//!   batcher, `NativeExecutor` — drains a synthetic mixed-precision request
//!   stream (including non-power-of-two FP6xFP6 and FP5) and reports
//!   latency/throughput plus the co-simulated FlexiBit accelerator
//!   estimates. Packed weights are cached per (model, weight format), so
//!   each precision configuration quantizes exactly once.
//!
//! The AOT/PJRT path this example used to exercise remains available behind
//! `--features pjrt` (see `rust/src/runtime/`); it is no longer required.
//!
//! Run: `cargo run --release --example serve_transformer`

use flexibit::arith::{gemm_ref, Format};
use flexibit::coordinator::{BatchPolicy, Request, Server, ServerConfig};
use flexibit::kernels::{gemm_default, NativeExecutor, PackedMatrix};
use flexibit::util::Rng;
use flexibit::workload::{ModelSpec, PrecisionPair};
use std::time::{Duration, Instant};

/// The request mix: FP6xFP6 (the paper's headline non-power-of-two point),
/// FP5, FP4xFP8, and a GPTQ-style INT4 x FP16.
fn precision_mix() -> Vec<PrecisionPair> {
    vec![
        PrecisionPair::of_bits(6, 6),
        PrecisionPair::of_bits(5, 6),
        PrecisionPair::of_bits(4, 8),
        PrecisionPair::new(Format::int(4), Format::default_fp(16)),
    ]
}

fn main() {
    // --- 1. Golden equivalence of the native kernel ----------------------
    let mut rng = Rng::new(7);
    let (m, k, n) = (16usize, 96usize, 48usize);
    for pair in precision_mix() {
        let a_codes = rng.codes(m * k, pair.a.bits());
        let w_codes = rng.codes(k * n, pair.w.bits());
        let a = PackedMatrix::from_codes(&a_codes, m, k, pair.a);
        let w = PackedMatrix::from_codes(&w_codes, k, n, pair.w);
        let got = gemm_default(&a, &w);
        let want = gemm_ref(&a_codes, pair.a, &w_codes, pair.w, m, k, n);
        assert_eq!(got, want, "native kernel diverged from golden at {}", pair.label());
        println!(
            "  {} native GEMM {}x{}x{} == golden reference (bit-exact); packed W {}B vs padded {}B",
            pair.label(),
            m,
            k,
            n,
            w.bytes(),
            w.padded_bytes()
        );
    }
    println!("numerics verified across all served precision pairs\n");

    // --- 2. Serve a mixed-precision request stream ------------------------
    let spec = ModelSpec::tiny();
    let executor = NativeExecutor::new().with_model(spec.clone(), 0xF1E81B);
    let cfg = ServerConfig {
        policy: BatchPolicy::default(),
        sim_config: flexibit::sim::mobile_a(),
        sim_model: spec.clone(),
    };
    let server = Server::start(cfg, Box::new(executor));

    let n_requests = 64u64;
    let pairs = precision_mix();
    let t0 = Instant::now();
    for i in 0..n_requests {
        let pair = pairs[(i % pairs.len() as u64) as usize];
        let input: Vec<f32> =
            (0..spec.seq * spec.d_model).map(|_| rng.gauss() as f32 * 0.5).collect();
        server.submit(Request {
            id: i,
            model: spec.name.to_string(),
            pair,
            input,
            dims: vec![spec.seq, spec.d_model],
            arrived: Instant::now(),
        });
    }
    // Drain.
    let drained = server.await_completed(n_requests, Duration::from_secs(120));
    let wall = t0.elapsed().as_secs_f64();
    let m = server.shutdown();
    assert!(drained, "drain timed out: {}/{n_requests} completed", m.requests_completed);

    println!("== native serving results ({n_requests} mixed-precision requests) ==");
    println!("  completed:          {}", m.requests_completed);
    println!(
        "  batches:            {} (mean size {:.1})",
        m.batches_executed,
        m.mean_batch_size()
    );
    println!("  precision switches: {}", m.reconfigurations);
    println!("  wall time:          {wall:.2}s  ({:.1} req/s)", m.throughput_rps(wall));
    println!(
        "  mean latency:       {:.1} ms (max {:.1} ms)",
        m.mean_latency_s() * 1e3,
        m.latency_max_s * 1e3
    );
    println!("  host exec time:     {:.2}s", m.host_exec_s);
    println!("== co-simulated FlexiBit accelerator (Mobile-A) ==");
    println!(
        "  simulated latency:  {:.3} ms/batch avg",
        m.sim_accel_s / m.batches_executed.max(1) as f64 * 1e3
    );
    println!("  simulated energy:   {:.3} mJ total", m.sim_energy_j * 1e3);
    assert_eq!(m.requests_completed, n_requests, "all requests must complete");
    println!("\nserve_transformer OK — any-precision serving with zero PJRT artifacts");
}
