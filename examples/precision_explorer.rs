//! Precision design-space explorer — the fine-grained quantization DSE the
//! paper argues flexible hardware unlocks (§2.2: "it allows more
//! fine-grained quantization design space exploration than power-of-two
//! precisions").
//!
//! Sweeps every weight width 4..=8 (and both FP6 format variants e3m2 /
//! e2m3, plus INT weights), measures (a) a quantization-quality proxy — the
//! RMS error of quantized random-Gaussian weights vs f32 — with the golden
//! arithmetic model, and (b) simulated latency/energy/EDP on Llama-2-7b at
//! Cloud-A, then prints the Pareto view a deployment engineer would use to
//! pick a precision. On fixed-pow2 hardware only the 4- and 8-bit rows are
//! reachable; FlexiBit exposes the whole frontier.
//!
//! Run: `cargo run --release --example precision_explorer`

use flexibit::arith::{decode, encode, Format};
use flexibit::baselines::{Accel, FlexiBitAccel, TensorCoreAccel};
use flexibit::report::{fmt_j, fmt_s, Table};
use flexibit::sim::{cloud_a, simulate_model};
use flexibit::util::Rng;
use flexibit::workload::{llama2_7b, PrecisionPair};

/// RMS quantization error of N(0, 0.04) weights (LLM-like scale) in `fmt`,
/// relative to the fp32 values.
fn rms_error(fmt: Format, rng: &mut Rng) -> f64 {
    let n = 20_000;
    let mut se = 0.0;
    for _ in 0..n {
        let v = rng.gauss() * 0.2;
        let q = decode(encode(v, fmt), fmt);
        se += (v - q) * (v - q);
    }
    (se / n as f64).sqrt()
}

fn main() {
    let mut rng = Rng::new(42);
    let cfg = cloud_a();
    let model = llama2_7b();
    let fb = FlexiBitAccel::new();
    let tc = TensorCoreAccel::new();

    let candidates: Vec<Format> = vec![
        Format::parse("e2m1").unwrap(),  // FP4
        Format::parse("e2m2").unwrap(),  // FP5
        Format::parse("e3m2").unwrap(),  // FP6 (paper default)
        Format::parse("e2m3").unwrap(),  // FP6 variant (FP6-LLM)
        Format::parse("e3m3").unwrap(),  // FP7
        Format::parse("e4m3").unwrap(),  // FP8
        Format::parse("int4").unwrap(),  // GPTQ-style INT4
        Format::parse("int8").unwrap(),
    ];

    let mut table = Table::new(
        "Precision DSE — Llama-2-7b @ Cloud-A, FP16 activations",
        &["W fmt", "bits", "RMS qerr", "FB latency", "FB energy", "FB EDP", "on pow2 HW?"],
    );
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for fmt in &candidates {
        let pair = PrecisionPair::new(*fmt, Format::parse("fp16").unwrap());
        let rep = simulate_model(&fb, &cfg, &model, pair);
        let err = rms_error(*fmt, &mut rng);
        let reachable = matches!(fmt.bits(), 4 | 8 | 16);
        rows.push((format!("{fmt}"), err, rep.edp()));
        table.row(vec![
            format!("{fmt}"),
            fmt.bits().to_string(),
            format!("{err:.5}"),
            fmt_s(rep.seconds),
            fmt_j(rep.energy_j),
            format!("{:.2}", rep.edp()),
            if reachable { "yes".into() } else { "FlexiBit only".to_string() },
        ]);
    }
    table.print();

    // Pareto frontier on (qerr, EDP).
    println!("\nPareto-optimal points (quality vs EDP):");
    for (name, err, edp) in &rows {
        let dominated = rows
            .iter()
            .any(|(n2, e2, d2)| n2 != name && *e2 <= *err && *d2 <= *edp && (*e2 < *err || *d2 < *edp));
        if !dominated {
            println!("  {name}  (qerr {err:.5}, EDP {edp:.2})");
        }
    }

    // What the same sweep looks like on fixed hardware: everything rounds
    // up to FP8/FP16 latency.
    let fp6 = PrecisionPair::of_bits(6, 16);
    let t_fb = simulate_model(&fb, &cfg, &model, fp6).seconds;
    let t_tc = simulate_model(&tc, &cfg, &model, fp6).seconds;
    println!(
        "\nFP6 weights on fixed-precision hardware run as FP16: {} vs FlexiBit {} ({:.2}x)",
        fmt_s(t_tc),
        fmt_s(t_fb),
        t_tc / t_fb
    );
}
